//! FPE→BPE scheduler (Fig. 7): "a scheduler is sitting between the
//! FPEs and BPE to decide which FPE can forward its result to BPE."
//!
//! Only one evicted pair can enter the BPE per arbitration slot; the
//! policy decides which FPE's forward queue is served.  Round-robin is
//! the hardware default; longest-queue-first is the ablation
//! (DESIGN.md §Ablations).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    RoundRobin,
    LongestQueueFirst,
}

/// Arbitrates among `n` FPE forward queues.
#[derive(Clone, Debug)]
pub struct Scheduler {
    policy: SchedPolicy,
    n: usize,
    cursor: usize,
    pub grants: u64,
}

impl Scheduler {
    pub fn new(n: usize, policy: SchedPolicy) -> Self {
        assert!(n > 0);
        Self {
            policy,
            n,
            cursor: 0,
            grants: 0,
        }
    }

    /// Grant a single known-nonempty queue — the event-driven fast
    /// path: the simulator presents evictions one at a time, so exactly
    /// one forward queue is occupied and both policies must pick it.
    /// Equivalent to [`Self::pick`] on a depth vector with
    /// `depths[group] = 1` and zeros elsewhere, without building it.
    #[inline]
    pub fn grant_single(&mut self, group: usize) -> usize {
        debug_assert!(group < self.n);
        self.cursor = (group + 1) % self.n;
        self.grants += 1;
        group
    }

    /// Pick the next queue to serve given current queue depths.
    /// Returns `None` if all queues are empty.
    pub fn pick(&mut self, depths: &[usize]) -> Option<usize> {
        let n = depths.len();
        let choice = match self.policy {
            SchedPolicy::RoundRobin => (0..n)
                .map(|i| (self.cursor + i) % n)
                .find(|&i| depths[i] > 0),
            SchedPolicy::LongestQueueFirst => depths
                .iter()
                .enumerate()
                .filter(|(_, &d)| d > 0)
                .max_by_key(|(i, &d)| (d, n - i)) // deterministic tiebreak
                .map(|(i, _)| i),
        }?;
        self.cursor = (choice + 1) % n;
        self.grants += 1;
        Some(choice)
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// Serialize the arbitration state (policy and queue count are
    /// static configuration and not serialized).
    pub(crate) fn snapshot_write(&self, out: &mut Vec<u8>) {
        crate::util::codec::put_u64(out, self.cursor as u64);
        crate::util::codec::put_u64(out, self.grants);
    }

    /// Restore state written by [`Self::snapshot_write`] in place.
    pub(crate) fn snapshot_read_into(
        &mut self,
        cur: &mut crate::util::codec::SnapCursor<'_>,
    ) -> Result<(), crate::util::codec::SnapshotError> {
        let cursor = cur.len()?;
        if cursor >= self.n {
            return Err(crate::util::codec::SnapshotError::Invalid(
                "scheduler cursor beyond queue count",
            ));
        }
        self.cursor = cursor;
        self.grants = cur.u64()?;
        Ok(())
    }
}

/// How ingress credit grants are split among concurrently busy trees.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GrantPolicy {
    /// Every tree may fill the whole reliability window — a flooder
    /// can monopolize the PE-input FIFO credit.
    #[default]
    Uniform,
    /// Credit is capped at each tree's weighted share of the window,
    /// so an aggressive tenant cannot starve a well-behaved neighbor.
    WeightedShare,
}

/// Weighted credit shares over a reliability window of `window` slots.
///
/// Stateless arithmetic — callers supply the tenant's weight and the
/// total weight of all currently-busy tenants; every share is floored
/// at one slot so no admitted tenant ever deadlocks at zero credit.
#[derive(Clone, Copy, Debug)]
pub struct WeightedGrants {
    window: u16,
}

impl WeightedGrants {
    pub fn new(window: u16) -> Self {
        Self {
            window: window.max(1),
        }
    }

    /// Window slots granted to a tenant of `weight` when the busy
    /// tenants' weights sum to `total_weight`.
    pub fn share(&self, weight: u64, total_weight: u64) -> u16 {
        if total_weight == 0 {
            return self.window;
        }
        let w = self.window as u64;
        (w * weight / total_weight).clamp(1, w) as u16
    }

    /// Cap an already-computed backpressure credit at the weighted share.
    pub fn cap(&self, credit: u16, weight: u64, total_weight: u64) -> u16 {
        credit.min(self.share(weight, total_weight))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles_fairly() {
        let mut s = Scheduler::new(3, SchedPolicy::RoundRobin);
        let depths = [1usize, 1, 1];
        let picks: Vec<usize> = (0..6).map(|_| s.pick(&depths).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(s.grants, 6);
    }

    #[test]
    fn round_robin_skips_empty() {
        let mut s = Scheduler::new(3, SchedPolicy::RoundRobin);
        assert_eq!(s.pick(&[0, 2, 0]), Some(1));
        assert_eq!(s.pick(&[0, 1, 3]), Some(2));
        assert_eq!(s.pick(&[0, 0, 0]), None);
    }

    #[test]
    fn grant_single_matches_pick_on_singleton_depths() {
        for policy in [SchedPolicy::RoundRobin, SchedPolicy::LongestQueueFirst] {
            let mut a = Scheduler::new(4, policy);
            let mut b = Scheduler::new(4, policy);
            for g in [2usize, 0, 3, 3, 1] {
                let mut depths = [0usize; 4];
                depths[g] = 1;
                assert_eq!(a.pick(&depths), Some(b.grant_single(g)), "{policy:?} g={g}");
            }
            assert_eq!(a.grants, b.grants);
        }
    }

    #[test]
    fn lqf_picks_deepest_deterministically() {
        let mut s = Scheduler::new(4, SchedPolicy::LongestQueueFirst);
        assert_eq!(s.pick(&[1, 5, 3, 5]), Some(1)); // tie → lowest index
        assert_eq!(s.pick(&[0, 0, 9, 1]), Some(2));
        assert_eq!(s.pick(&[0, 0, 0, 0]), None);
    }

    #[test]
    fn weighted_grants_split_the_window_proportionally() {
        let g = WeightedGrants::new(64);
        assert_eq!(g.share(1, 2), 32); // equal split between two
        assert_eq!(g.share(3, 4), 48); // 3:1 split
        assert_eq!(g.share(1, 4), 16);
    }

    #[test]
    fn weighted_grants_floor_at_one_and_ceil_at_window() {
        let g = WeightedGrants::new(64);
        // A tiny weight among many still gets one slot, never zero.
        assert_eq!(g.share(1, 1000), 1);
        // A dominant weight never exceeds the window.
        assert_eq!(g.share(1000, 1000), 64);
        // Degenerate one-slot window stays at one.
        assert_eq!(WeightedGrants::new(0).share(1, 8), 1);
    }

    #[test]
    fn weighted_grants_zero_total_means_uncontended() {
        // No busy tenants registered: full window (solo fast path).
        assert_eq!(WeightedGrants::new(64).share(5, 0), 64);
    }

    #[test]
    fn cap_never_raises_credit() {
        let g = WeightedGrants::new(64);
        // Backpressure already throttled below the share: keep it.
        assert_eq!(g.cap(4, 1, 2), 4);
        // Credit above the share: clamp to the share.
        assert_eq!(g.cap(60, 1, 2), 32);
    }
}
