//! Deterministic switch-state snapshots — the substrate of warm-standby
//! failover (checkpointed replication + promotion).
//!
//! A [`SwitchSnapshot`] holds one tree's complete aggregation state as
//! a set of independently-encoded *sections*: engine core, each FPE
//! hash table, BPE meta + each DRAM region, dedup windows, and the
//! tree/tenant metadata.  Sectioning is what makes checkpoints
//! *incremental*: [`SnapshotDelta::between`] ships only the sections
//! whose bytes changed since the previous checkpoint, and the standby
//! patches its copy with [`SnapshotDelta::apply`] — guarded by a base
//! index so a delta can never be applied to the wrong base.
//!
//! The wire format is hostile-input safe end to end: every decode path
//! is bounds-checked through [`SnapCursor`], returns typed
//! [`SnapshotError`]s (never panics), and never allocates from an
//! unvalidated length (see `tests::decode_survives_fuzz`).  Snapshots
//! are byte-deterministic — the same switch state always serializes to
//! the same bytes (sections are id-sorted, sparse tables bucket-sorted)
//! — so "did anything change" is a byte comparison, which is exactly
//! what the delta builder does.

use crate::protocol::AggOp;
use crate::util::codec::{self, SnapCursor, SnapshotError};
use std::collections::BTreeMap;

/// Section ids.  Fixed ids 1–4 hold singleton state; per-memory-region
/// sections live at a base offset + group index so an incremental
/// checkpoint can address one FPE table or one BPE DRAM region alone.
pub const SEC_META: u32 = 1;
pub const SEC_ENGINE: u32 = 2;
pub const SEC_DEDUP: u32 = 3;
pub const SEC_BPE_META: u32 = 4;
pub const SEC_FPE_BASE: u32 = 0x100;
pub const SEC_BPE_REGION_BASE: u32 = 0x200;

const SNAP_MAGIC: u32 = 0x5357_4147; // "SWAG"
const DELTA_MAGIC: u32 = 0x5357_4144; // "SWAD"
const VERSION: u16 = 1;

/// Wire encoding of [`AggOp`] inside the META section.
pub(crate) fn op_code(op: AggOp) -> u8 {
    match op {
        AggOp::Sum => 0,
        AggOp::Max => 1,
        AggOp::Min => 2,
    }
}

pub(crate) fn op_from_code(code: u8) -> Option<AggOp> {
    match code {
        0 => Some(AggOp::Sum),
        1 => Some(AggOp::Max),
        2 => Some(AggOp::Min),
        _ => None,
    }
}

/// One tree's complete, deterministic aggregation-state image.
///
/// Build with [`crate::switch::SwitchAggSwitch::snapshot_tree`],
/// install with [`crate::switch::SwitchAggSwitch::restore_tree`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SwitchSnapshot {
    sections: BTreeMap<u32, Vec<u8>>,
}

impl SwitchSnapshot {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install (or replace) one section's bytes.
    pub(crate) fn insert(&mut self, id: u32, bytes: Vec<u8>) {
        self.sections.insert(id, bytes);
    }

    pub fn section(&self, id: u32) -> Option<&[u8]> {
        self.sections.get(&id).map(|b| b.as_slice())
    }

    pub fn ids(&self) -> impl Iterator<Item = u32> + '_ {
        self.sections.keys().copied()
    }

    pub fn n_sections(&self) -> usize {
        self.sections.len()
    }

    /// Total serialized size in bytes (what a full checkpoint ships).
    pub fn encoded_len(&self) -> usize {
        // magic + version + count, then per section: id + len + bytes.
        10 + self
            .sections
            .values()
            .map(|b| 12 + b.len())
            .sum::<usize>()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        codec::put_u32(&mut out, SNAP_MAGIC);
        codec::put_u16(&mut out, VERSION);
        codec::put_u32(&mut out, self.sections.len() as u32);
        for (&id, bytes) in &self.sections {
            codec::put_u32(&mut out, id);
            codec::put_u64(&mut out, bytes.len() as u64);
            out.extend_from_slice(bytes);
        }
        out
    }

    /// Decode a serialized snapshot.  Structural validation only — the
    /// section *contents* are validated against the restore target's
    /// geometry by `restore_tree` (the container cannot know it).
    /// Hostile input yields typed errors: truncation at any offset,
    /// bad magic/version, non-canonical section order, or trailing
    /// bytes all fail cleanly without panics or unbounded allocation.
    pub fn from_bytes(buf: &[u8]) -> Result<Self, SnapshotError> {
        let mut cur = SnapCursor::new(buf);
        if cur.u32()? != SNAP_MAGIC {
            return Err(SnapshotError::BadMagic);
        }
        let version = cur.u16()?;
        if version != VERSION {
            return Err(SnapshotError::BadVersion(version));
        }
        let n = cur.u32()?;
        let mut sections = BTreeMap::new();
        let mut last: Option<u32> = None;
        for _ in 0..n {
            let id = cur.u32()?;
            if last.is_some_and(|l| id <= l) {
                return Err(SnapshotError::Invalid("sections not strictly increasing"));
            }
            last = Some(id);
            let len = cur.len()?;
            // `bytes` bounds-checks `len` against the remaining input
            // before we copy, so a hostile length cannot over-allocate.
            sections.insert(id, cur.bytes(len)?.to_vec());
        }
        cur.finish()?;
        Ok(Self { sections })
    }
}

/// The difference between two consecutive checkpoints of one tree:
/// only the sections whose bytes changed, plus any that disappeared.
/// `base_index` names the checkpoint this delta patches — applying it
/// to any other base is a hard error, because a patched-together
/// snapshot would silently diverge from the primary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapshotDelta {
    base_index: u64,
    sections: BTreeMap<u32, Vec<u8>>,
    removed: Vec<u32>,
}

impl SnapshotDelta {
    /// Diff `next` against `prev` (the checkpoint numbered
    /// `base_index`).  Byte-equal sections are skipped — determinism of
    /// the snapshot encoding is what makes this sound.
    pub fn between(base_index: u64, prev: &SwitchSnapshot, next: &SwitchSnapshot) -> Self {
        let mut sections = BTreeMap::new();
        for (&id, bytes) in &next.sections {
            if prev.sections.get(&id) != Some(bytes) {
                sections.insert(id, bytes.clone());
            }
        }
        let removed: Vec<u32> = prev
            .sections
            .keys()
            .filter(|id| !next.sections.contains_key(id))
            .copied()
            .collect();
        Self {
            base_index,
            sections,
            removed,
        }
    }

    pub fn base_index(&self) -> u64 {
        self.base_index
    }

    /// Number of changed/new sections this delta carries.
    pub fn n_changed(&self) -> usize {
        self.sections.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sections.is_empty() && self.removed.is_empty()
    }

    /// Patch `base` (which must be checkpoint `base_index` — verified
    /// by the caller via [`Self::base_index`]) into the next full
    /// snapshot.
    pub fn apply(&self, base: &SwitchSnapshot) -> SwitchSnapshot {
        let mut out = base.clone();
        for id in &self.removed {
            out.sections.remove(id);
        }
        for (&id, bytes) in &self.sections {
            out.sections.insert(id, bytes.clone());
        }
        out
    }

    /// Total serialized size in bytes (what an incremental checkpoint
    /// ships instead of [`SwitchSnapshot::encoded_len`]).
    pub fn encoded_len(&self) -> usize {
        10 + 8 + 4
            + self.removed.len() * 4
            + self
                .sections
                .values()
                .map(|b| 12 + b.len())
                .sum::<usize>()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        codec::put_u32(&mut out, DELTA_MAGIC);
        codec::put_u16(&mut out, VERSION);
        codec::put_u64(&mut out, self.base_index);
        codec::put_u32(&mut out, self.removed.len() as u32);
        for &id in &self.removed {
            codec::put_u32(&mut out, id);
        }
        codec::put_u32(&mut out, self.sections.len() as u32);
        for (&id, bytes) in &self.sections {
            codec::put_u32(&mut out, id);
            codec::put_u64(&mut out, bytes.len() as u64);
            out.extend_from_slice(bytes);
        }
        out
    }

    pub fn from_bytes(buf: &[u8]) -> Result<Self, SnapshotError> {
        let mut cur = SnapCursor::new(buf);
        if cur.u32()? != DELTA_MAGIC {
            return Err(SnapshotError::BadMagic);
        }
        let version = cur.u16()?;
        if version != VERSION {
            return Err(SnapshotError::BadVersion(version));
        }
        let base_index = cur.u64()?;
        let n_removed = cur.u32()? as usize;
        let mut removed =
            Vec::with_capacity(codec::clamped_capacity(n_removed, cur.remaining(), 4));
        let mut last: Option<u32> = None;
        for _ in 0..n_removed {
            let id = cur.u32()?;
            if last.is_some_and(|l| id <= l) {
                return Err(SnapshotError::Invalid("removed ids not strictly increasing"));
            }
            last = Some(id);
            removed.push(id);
        }
        let n = cur.u32()?;
        let mut sections = BTreeMap::new();
        let mut last: Option<u32> = None;
        for _ in 0..n {
            let id = cur.u32()?;
            if last.is_some_and(|l| id <= l) {
                return Err(SnapshotError::Invalid("sections not strictly increasing"));
            }
            last = Some(id);
            let len = cur.len()?;
            sections.insert(id, cur.bytes(len)?.to_vec());
        }
        cur.finish()?;
        Ok(Self {
            base_index,
            sections,
            removed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{AggOp, AggregationPacket, Key, KvPair, TreeConfig, TreeId, Value};
    use crate::switch::config::SwitchConfig;
    use crate::switch::switch_sim::{IngestSink, SwitchAggSwitch};
    use crate::util::rng::Pcg32;

    fn configured(children: u16) -> SwitchAggSwitch {
        let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(16 << 10, Some(256 << 10)));
        sw.configure(&[TreeConfig {
            tree: TreeId(1),
            children,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        sw
    }

    fn pairs(n: usize, distinct: u64, seed: u64) -> Vec<KvPair> {
        let mut rng = Pcg32::new(seed);
        (0..n)
            .map(|_| {
                let id = rng.gen_range_u64(distinct);
                KvPair::new(Key::from_id(id, 16 + (id % 49) as usize), 1)
            })
            .collect()
    }

    fn rel_pkt(tree: TreeId, child: u16, seq: u32, pairs: Vec<KvPair>, eot: bool) -> AggregationPacket {
        AggregationPacket {
            tree,
            op: AggOp::Sum,
            eot,
            rel: Some(crate::protocol::RelHeader {
                child,
                epoch: 0,
                seq,
            }),
            pairs,
        }
    }

    /// A mid-job switch with engine state, dedup windows, and stats.
    fn warm_switch() -> SwitchAggSwitch {
        let mut sw = configured(2);
        let mut sink = IngestSink::new();
        for (c, seed) in [(0u16, 5u64), (1, 6)] {
            for (i, chunk) in pairs(600, 150, seed).chunks(40).enumerate() {
                let pkt = rel_pkt(TreeId(1), c, i as u32 + 1, chunk.to_vec(), false);
                sw.ingest_reliable_one(TreeId(1), &pkt, &mut sink);
            }
        }
        sw
    }

    #[test]
    fn container_roundtrip_is_byte_exact() {
        let sw = warm_switch();
        let snap = sw.snapshot_tree(TreeId(1)).unwrap();
        assert!(snap.n_sections() >= 4, "expected META/ENGINE/DEDUP/FPE sections");
        let bytes = snap.to_bytes();
        assert_eq!(bytes.len(), snap.encoded_len());
        let back = SwitchSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back, snap);
        // Determinism: re-snapshotting unchanged state is byte-equal.
        assert_eq!(sw.snapshot_tree(TreeId(1)).unwrap().to_bytes(), bytes);
    }

    #[test]
    fn restore_continues_ingest_byte_identically() {
        let mut primary = warm_switch();
        let snap = primary.snapshot_tree(TreeId(1)).unwrap();

        // Standby: same static config, fresh state, then restore.
        let mut standby = configured(2);
        let tree = standby.restore_tree(&snap).unwrap();
        assert_eq!(tree, TreeId(1));

        // Both switches now run the identical suffix to completion.
        let mut sink_p = IngestSink::new();
        let mut sink_s = IngestSink::new();
        for (c, seed) in [(0u16, 25u64), (1, 26)] {
            let suffix = pairs(300, 150, seed);
            for (i, chunk) in suffix.chunks(40).enumerate() {
                let last = (i + 1) * 40 >= suffix.len();
                let pkt = rel_pkt(TreeId(1), c, 16 + i as u32, chunk.to_vec(), last);
                let ack_p = primary.ingest_reliable_one(TreeId(1), &pkt, &mut sink_p);
                let ack_s = standby.ingest_reliable_one(TreeId(1), &pkt, &mut sink_s);
                assert_eq!(ack_p, ack_s, "acks diverged at child {c} pkt {i}");
            }
        }
        assert_eq!(sink_p.flushes, 1);
        assert_eq!(sink_s.flushes, sink_p.flushes);
        assert_eq!(sink_s.forwarded, sink_p.forwarded);
        assert_eq!(sink_s.flushed, sink_p.flushed);
        primary.finalize(TreeId(1));
        standby.finalize(TreeId(1));
        assert_eq!(
            format!("{:?}", standby.stats(TreeId(1)).unwrap()),
            format!("{:?}", primary.stats(TreeId(1)).unwrap())
        );
        assert_eq!(standby.dedup_stats(TreeId(1)), primary.dedup_stats(TreeId(1)));
    }

    #[test]
    fn restore_replays_retransmissions_as_duplicates() {
        // Bounded replay: packets the primary had already admitted are
        // re-offered to the restored standby (the sender cannot know
        // the checkpoint boundary) and must dedup, not double-count.
        let mut primary = configured(1);
        let stream = pairs(400, 90, 11);
        let mut sink = IngestSink::new();
        let chunks: Vec<&[KvPair]> = stream.chunks(40).collect();
        for (i, chunk) in chunks.iter().enumerate().take(6) {
            let pkt = rel_pkt(TreeId(1), 0, i as u32 + 1, chunk.to_vec(), false);
            primary.ingest_reliable_one(TreeId(1), &pkt, &mut sink);
        }
        let snap = primary.snapshot_tree(TreeId(1)).unwrap();

        let mut standby = configured(1);
        standby.restore_tree(&snap).unwrap();
        assert_eq!(standby.dedup_cum(TreeId(1), 0), 6);
        let mut sink_s = IngestSink::new();
        // Replay from seq 3 (inside the admitted prefix) to the end.
        for (i, chunk) in chunks.iter().enumerate().skip(2) {
            let last = i + 1 == chunks.len();
            let pkt = rel_pkt(TreeId(1), 0, i as u32 + 1, chunk.to_vec(), last);
            standby.ingest_reliable_one(TreeId(1), &pkt, &mut sink_s);
        }
        assert_eq!(sink_s.flushes, 1);
        let d = standby.dedup_stats(TreeId(1));
        assert_eq!(d.dup_drops, 4, "seqs 3..=6 replayed as duplicates");
        let total: Value = sink.forwarded.iter().map(|p| p.value).sum::<Value>()
            + sink_s.forwarded.iter().map(|p| p.value).sum::<Value>()
            + sink_s.flushed.iter().map(|p| p.value).sum::<Value>();
        let want: Value = stream.iter().map(|p| p.value).sum();
        assert_eq!(total, want, "replay must not double-count");
    }

    #[test]
    fn restore_rejects_mismatched_target() {
        let primary = warm_switch();
        let snap = primary.snapshot_tree(TreeId(1)).unwrap();
        // Not resident.
        let mut empty = SwitchAggSwitch::new(SwitchConfig::scaled(16 << 10, Some(256 << 10)));
        assert_eq!(
            empty.restore_tree(&snap),
            Err(SnapshotError::Geometry("tree not resident on restore target"))
        );
        // Wrong fan-in.
        let mut wrong = configured(3);
        assert_eq!(
            wrong.restore_tree(&snap),
            Err(SnapshotError::Geometry("tree configuration"))
        );
        // Wrong memory geometry (different FPE budget).
        let mut small = SwitchAggSwitch::new(SwitchConfig::scaled(8 << 10, Some(256 << 10)));
        small.configure(&[TreeConfig {
            tree: TreeId(1),
            children: 2,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        assert!(small.restore_tree(&snap).is_err());
    }

    #[test]
    fn delta_ships_only_dirtied_sections_and_applies_exactly() {
        let mut sw = warm_switch();
        let snap0 = sw.snapshot_tree(TreeId(1)).unwrap();
        // Quiet interval: the delta is empty.
        let snap_same = sw.snapshot_tree(TreeId(1)).unwrap();
        let d = SnapshotDelta::between(0, &snap0, &snap_same);
        assert!(d.is_empty());

        // One more packet dirties the engine core, stats, dedup, and
        // the touched FPE tables — but not every memory region.
        let mut sink = IngestSink::new();
        let pkt = rel_pkt(TreeId(1), 0, 16, pairs(30, 10, 40), false);
        sw.ingest_reliable_one(TreeId(1), &pkt, &mut sink);
        let snap1 = sw.snapshot_tree(TreeId(1)).unwrap();
        let d = SnapshotDelta::between(0, &snap0, &snap1);
        assert!(!d.is_empty());
        assert!(
            d.n_changed() < snap1.n_sections(),
            "incremental checkpoint must skip untouched sections"
        );
        assert!(d.encoded_len() < snap1.encoded_len());
        assert_eq!(d.apply(&snap0), snap1);

        // Delta wire round trip.
        let back = SnapshotDelta::from_bytes(&d.to_bytes()).unwrap();
        assert_eq!(back, d);
        assert_eq!(back.base_index(), 0);
    }

    #[test]
    fn decode_survives_fuzz() {
        // Truncation at every prefix, a sweep of bit flips, and length
        // inflation: never a panic, never an over-reserve — either a
        // clean parse or a typed error.
        let sw = warm_switch();
        let bytes = sw.snapshot_tree(TreeId(1)).unwrap().to_bytes();
        for n in 0..bytes.len() {
            assert!(SwitchSnapshot::from_bytes(&bytes[..n]).is_err());
        }
        for i in (0..bytes.len()).step_by(7) {
            let mut m = bytes.clone();
            m[i] ^= 0x80;
            let _ = SwitchSnapshot::from_bytes(&m); // must not panic
        }
        // Inflate the first section length field far past the input.
        let mut m = bytes.clone();
        let len_off = 4 + 2 + 4 + 4; // magic+version+count+first id
        m[len_off..len_off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(SwitchSnapshot::from_bytes(&m).is_err());
        // Same hostility against the delta decoder.
        let empty = SnapshotDelta::between(3, &SwitchSnapshot::new(), &SwitchSnapshot::new());
        let dbytes = empty.to_bytes();
        for n in 0..dbytes.len() {
            assert!(SnapshotDelta::from_bytes(&dbytes[..n]).is_err());
        }
    }

    #[test]
    fn restored_switch_rejects_malformed_section_contents() {
        // A structurally-valid container whose DEDUP section is garbage
        // must fail typed and leave the target's dedup map untouched.
        let primary = warm_switch();
        let mut snap = primary.snapshot_tree(TreeId(1)).unwrap();
        snap.insert(SEC_DEDUP, vec![0xFF; 64]);
        let mut standby = configured(2);
        assert!(standby.restore_tree(&snap).is_err());
        assert_eq!(standby.dedup_cum(TreeId(1), 0), 0);
    }
}
