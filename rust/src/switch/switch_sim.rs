//! The assembled SwitchAgg device (Fig. 4): header extraction →
//! payload analyzer → crossbar → FPEs → scheduler → BPE, plus the
//! forwarding and configuration modules.
//!
//! Timing: aggregation pairs arrive paced by the 10 Gbps input link
//! (16 B datapath beats at 200 MHz ⇒ 0.16 cycles/byte), flow through
//! the crossbar (2 cycles), are accepted by their group's FPE every
//! `fpe_interval` cycles and, on eviction, ride the scheduler into the
//! BPE.  All FIFO occupancy / full events are recorded per Table 2;
//! per-stage latencies per Table 3.
//!
//! # Allocation discipline
//!
//! The per-pair loop is the simulator's hot path, so the ingest API is
//! sink-based: callers own an [`IngestSink`] whose buffers are reused
//! across packets, and the stream entry points
//! ([`SwitchAggSwitch::ingest_stream`] /
//! [`SwitchAggSwitch::ingest_child_streams`]) walk MTU-sized *chunks*
//! of the caller's pair slice instead of materializing packet objects
//! — in steady state the data plane performs no per-packet heap
//! allocation (see `EXPERIMENTS.md` §Perf).

use crate::protocol::packet::MtuChunks;
use crate::protocol::vector::VectorChunks;
use crate::protocol::{
    AggAckPacket, AggOp, AggregationPacket, Key, KvPair, RelWindow, TreeConfig, TreeId, Value,
    VectorBatch,
};
use crate::sim::clock::{cycles_to_secs, Cycles, CLOCK_HZ};
use crate::switch::config::{ConfigModule, SwitchConfig};
use crate::switch::forwarding::Forwarding;
use crate::switch::header_extract::HeaderExtract;
use crate::switch::integrity::IntegrityError;
use crate::switch::parallel::Parallelism;
use crate::switch::reliability::{backpressure_credit, Admit, CreditPolicy, DedupStats, DedupWindow};
use crate::switch::scheduler::{GrantPolicy, WeightedGrants};
use crate::switch::snapshot::{self, SwitchSnapshot};
use crate::switch::tenant::{
    AdmissionError, EvictedResidents, QuotaRequest, TenantDirectory, TreeEngine,
};
use crate::util::codec::{self, SnapCursor, SnapshotError};
use std::collections::BTreeMap;

/// Per-tree aggregate statistics (port counters, §6.2 methodology).
#[derive(Clone, Debug, Default)]
pub struct SwitchStats {
    pub pairs_in: u64,
    pub bytes_in: u64,
    pub packets_in: u64,
    /// Pairs forwarded downstream mid-stream (evictions/overflow).
    pub pairs_out_stream: u64,
    /// Pairs flushed at end of tree.
    pub pairs_out_flush: u64,
    pub bytes_out: u64,
    pub fpe_aggregated: u64,
    pub fpe_inserted: u64,
    pub fpe_evicted: u64,
    pub bpe_aggregated: u64,
    pub bpe_inserted: u64,
    pub bpe_overflowed: u64,
    pub fifo_writes: u64,
    pub fifo_full_events: u64,
    /// Peak PE-input FIFO occupancy across all FPEs and the BPE
    /// (capped at `fifo_cap`) — the queue-depth signal the
    /// congestion-aware credit advertisement and the incast experiment
    /// read (`sim::Fifo::max_occupancy`'s counterpart on the analytic
    /// FIFO model).
    pub fifo_max_occupancy: u64,
    /// Times the sharded engine silently took the serial loop because
    /// an end-of-tree flush would have split the chunk stream —
    /// benchmarks must check this before attributing numbers to the
    /// sharded path.
    pub fallback_serial: u64,
    /// Packets that arrived for this tree while it was not configured
    /// (e.g. evicted under churn, or data racing ahead of Configure) —
    /// counted and dropped at the switch boundary instead of
    /// panicking.  Seeded from the switch-level accumulator when the
    /// tree's engine is (re)built, so the count survives engine churn.
    pub unconfigured_drops: u64,
    /// Lane-combines whose result clamped at the value-range boundary
    /// (SUM saturation), summed over every FPE table and BPE region —
    /// rolled from `HashTable::saturated`, the same single accounting
    /// point as the combine counters, so no engine path can clamp a
    /// count silently.  Serial- and sharded-engine runs report the
    /// same value (the per-key combine sequences are pinned equal).
    pub saturated_combines: u64,
    pub flush_cycles: Cycles,
    /// Cycle at which the last pair finished processing.
    pub makespan_cycles: Cycles,
}

impl SwitchStats {
    /// Paper's reduction ratio R = 1 − out/in over wire bytes.
    pub fn reduction_ratio(&self) -> f64 {
        if self.bytes_in == 0 {
            0.0
        } else {
            1.0 - self.bytes_out as f64 / self.bytes_in as f64
        }
    }

    /// Table 2 "Full-time ratio".
    pub fn fifo_full_ratio(&self) -> f64 {
        if self.fifo_writes == 0 {
            0.0
        } else {
            self.fifo_full_events as f64 / self.fifo_writes as f64
        }
    }

    /// Effective processing throughput in bytes/sec over the makespan.
    pub fn throughput_bytes_per_sec(&self) -> f64 {
        if self.makespan_cycles == 0 {
            0.0
        } else {
            self.bytes_in as f64 * CLOCK_HZ as f64 / self.makespan_cycles as f64
        }
    }

    /// Serialize every counter in declaration order (all 64-bit).
    pub(crate) fn snapshot_write(&self, out: &mut Vec<u8>) {
        for v in [
            self.pairs_in,
            self.bytes_in,
            self.packets_in,
            self.pairs_out_stream,
            self.pairs_out_flush,
            self.bytes_out,
            self.fpe_aggregated,
            self.fpe_inserted,
            self.fpe_evicted,
            self.bpe_aggregated,
            self.bpe_inserted,
            self.bpe_overflowed,
            self.fifo_writes,
            self.fifo_full_events,
            self.fifo_max_occupancy,
            self.fallback_serial,
            self.unconfigured_drops,
            self.saturated_combines,
            self.flush_cycles,
            self.makespan_cycles,
        ] {
            codec::put_u64(out, v);
        }
    }

    /// Restore state written by [`Self::snapshot_write`] in place.
    pub(crate) fn snapshot_read_into(
        &mut self,
        cur: &mut SnapCursor<'_>,
    ) -> Result<(), SnapshotError> {
        for v in [
            &mut self.pairs_in,
            &mut self.bytes_in,
            &mut self.packets_in,
            &mut self.pairs_out_stream,
            &mut self.pairs_out_flush,
            &mut self.bytes_out,
            &mut self.fpe_aggregated,
            &mut self.fpe_inserted,
            &mut self.fpe_evicted,
            &mut self.bpe_aggregated,
            &mut self.bpe_inserted,
            &mut self.bpe_overflowed,
            &mut self.fifo_writes,
            &mut self.fifo_full_events,
            &mut self.fifo_max_occupancy,
            &mut self.fallback_serial,
            &mut self.unconfigured_drops,
            &mut self.saturated_combines,
            &mut self.flush_cycles,
            &mut self.makespan_cycles,
        ] {
            *v = cur.u64()?;
        }
        Ok(())
    }
}

/// Everything the switch emits while ingesting one packet (owning
/// variant, built by the compatibility wrapper [`SwitchAggSwitch::ingest`]).
#[derive(Clone, Debug, Default)]
pub struct IngestOutput {
    /// Pairs leaving downstream immediately (evictions, overflow).
    pub forwarded: Vec<KvPair>,
    /// Set when this packet completed the tree (all children EoT):
    /// the flushed residents.
    pub flushed: Option<Vec<KvPair>>,
}

/// Caller-owned, reusable output sink for the ingest path: the switch
/// *appends*, the caller clears — so a steady-state ingest loop does no
/// per-packet heap allocation once the buffers have warmed up.
#[derive(Clone, Debug, Default)]
pub struct IngestSink {
    /// Pairs leaving downstream immediately (evictions, overflow).
    pub forwarded: Vec<KvPair>,
    /// Residents streamed out by end-of-tree flushes.
    pub flushed: Vec<KvPair>,
    /// Number of tree completions (flushes) recorded since `clear`.
    pub flushes: u32,
    /// Reused engine-drain scratch.
    scratch: Vec<(Key, Value)>,
}

impl IngestSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty all buffers, keeping their capacity.
    pub fn clear(&mut self) {
        self.forwarded.clear();
        self.flushed.clear();
        self.flushes = 0;
        self.scratch.clear();
    }

    /// Total buffer capacity in elements — used by tests/benches to
    /// assert that steady-state ingest stops allocating.
    pub fn capacity(&self) -> usize {
        self.forwarded.capacity() + self.flushed.capacity() + self.scratch.capacity()
    }
}

/// Caller-owned, reusable output sink for the W-lane vector ingest
/// path — the columnar counterpart of [`IngestSink`]: the switch
/// *appends*, the caller clears, so steady-state vector ingest does no
/// per-packet heap allocation once the buffers have warmed up.
#[derive(Clone, Debug)]
pub struct VectorSink {
    /// W-lane pairs leaving downstream immediately (evictions,
    /// overflow), in emission order.
    pub forwarded: VectorBatch,
    /// Residents streamed out by end-of-tree flushes.
    pub flushed: VectorBatch,
    /// Number of tree completions (flushes) recorded since `clear`.
    pub flushes: u32,
    /// Reused columnar engine-drain scratch.
    scratch_keys: Vec<Key>,
    scratch_vals: Vec<Value>,
}

impl VectorSink {
    pub fn new(lanes: usize) -> Self {
        Self {
            forwarded: VectorBatch::new(lanes),
            flushed: VectorBatch::new(lanes),
            flushes: 0,
            scratch_keys: Vec::new(),
            scratch_vals: Vec::new(),
        }
    }

    pub fn lanes(&self) -> usize {
        self.forwarded.lanes()
    }

    /// Empty all buffers, keeping their capacity.
    pub fn clear(&mut self) {
        self.forwarded.clear();
        self.flushed.clear();
        self.flushes = 0;
        self.scratch_keys.clear();
        self.scratch_vals.clear();
    }

    /// Total buffer capacity in elements (steady-state alloc checks).
    pub fn capacity(&self) -> usize {
        self.forwarded.capacity()
            + self.flushed.capacity()
            + self.scratch_keys.capacity()
            + self.scratch_vals.capacity()
    }
}

/// Concatenate a vector sink's stream + flush output (flushes only
/// happen after the final EoT, so this preserves emission order).
pub fn vector_sink_to_batch(sink: &VectorSink) -> VectorBatch {
    let mut out = VectorBatch::with_capacity(
        sink.forwarded.lanes(),
        sink.forwarded.len() + sink.flushed.len(),
    );
    out.extend_from_batch(&sink.forwarded);
    out.extend_from_batch(&sink.flushed);
    out
}


/// The full switch.
pub struct SwitchAggSwitch {
    cfg: SwitchConfig,
    pub header_extract: HeaderExtract,
    pub forwarding: Forwarding,
    config_module: ConfigModule,
    /// Every resident tree (legacy static-split and quota-admitted
    /// alike) plus the FPE/BPE memory ledger — see `switch::tenant`.
    tenants: TenantDirectory,
    /// Per-tree value lane width (W); absent = 1 (scalar).  Announced
    /// via [`Self::configure_vector`] and applied at engine (re)build.
    lane_width: BTreeMap<TreeId, usize>,
    /// Exactly-once admission state for reliable streams, one window
    /// per `(tree, child port)` (see `switch::reliability`); created
    /// lazily on the first reliable packet of a stream.
    dedup: BTreeMap<(TreeId, u16), DedupWindow>,
    /// Window every dedup bitmap is sized from — the same [`RelWindow`]
    /// the session config hands its senders, so the two ends cannot
    /// disagree.
    rel_window: RelWindow,
    /// How acks fill their credit field (constant window vs
    /// FIFO-backpressure scaled).
    credit_policy: CreditPolicy,
    /// Per-tree job epoch (incarnation fence): reliable packets whose
    /// rel header carries another epoch are dropped at admission.
    /// Absent = 0, the initial incarnation.
    epochs: BTreeMap<TreeId, u16>,
    /// Per-tree count of epoch-fenced packets.  Simulator accounting:
    /// unlike `epochs`/`dedup`, this survives [`Self::crash`].
    stale_epoch: BTreeMap<TreeId, u64>,
    /// Per-tree count of packets dropped because the tree was not
    /// configured (satellite of the tenancy work: under churn this is
    /// reachable from the wire and must not panic).  Simulator
    /// accounting like `stale_epoch`: survives [`Self::crash`].
    unconfigured: BTreeMap<TreeId, u64>,
    /// Per-tree count of packets rejected at ingress because their
    /// CRC32C trailer did not match the payload (wire corruption
    /// detected and contained at the switch; the sender's reliable
    /// layer retransmits).  Simulator accounting like `stale_epoch`:
    /// survives [`Self::crash`].
    corrupt_drops: BTreeMap<TreeId, u64>,
    /// How ack credit is granted across tenants (uniform by default;
    /// weighted per-tenant shares for isolation under overload).
    grant_policy: GrantPolicy,
    /// Reused sink for the stream entry points.
    sink: IngestSink,
}

impl SwitchAggSwitch {
    pub fn new(cfg: SwitchConfig) -> Self {
        Self {
            cfg,
            header_extract: HeaderExtract::new(),
            forwarding: Forwarding::new(),
            config_module: ConfigModule::new(),
            tenants: TenantDirectory::new(),
            lane_width: BTreeMap::new(),
            dedup: BTreeMap::new(),
            rel_window: RelWindow::default(),
            credit_policy: CreditPolicy::default(),
            epochs: BTreeMap::new(),
            stale_epoch: BTreeMap::new(),
            unconfigured: BTreeMap::new(),
            corrupt_drops: BTreeMap::new(),
            grant_policy: GrantPolicy::default(),
            sink: IngestSink::new(),
        }
    }

    /// Size future dedup windows from `w` (the session's shared
    /// [`RelWindow`]).  Must precede the first reliable packet — live
    /// bitmaps cannot be resized without corrupting their streams.
    pub fn set_rel_window(&mut self, w: RelWindow) {
        assert!(
            self.dedup.is_empty() || w == self.rel_window,
            "reliable window must be set before the first reliable packet"
        );
        self.rel_window = w;
    }

    /// Select how acks advertise credit (takes effect immediately;
    /// the default [`CreditPolicy::WindowOnly`] is the PR 4 behavior).
    pub fn set_credit_policy(&mut self, policy: CreditPolicy) {
        self.credit_policy = policy;
    }

    /// The tree's current epoch (0 until [`Self::begin_epoch`] moves
    /// it).
    pub fn tree_epoch(&self, tree: TreeId) -> u16 {
        self.epochs.get(&tree).copied().unwrap_or(0)
    }

    /// Enter a new incarnation of one tree's job: the controller bumped
    /// the epoch (after a restart, or a membership re-plan), so every
    /// reliable sequence space of the tree restarts — its dedup windows
    /// are discarded and packets still carrying an older epoch are
    /// fenced at admission from now on.  The caller is responsible for
    /// having re-applied the tree's Configure first (engines rebuild
    /// there); epochs may repeat (idempotent re-push) but never regress.
    pub fn begin_epoch(&mut self, tree: TreeId, epoch: u16) {
        let cur = self.tree_epoch(tree);
        assert!(epoch >= cur, "epoch must not regress ({epoch} < {cur})");
        self.epochs.insert(tree, epoch);
        self.dedup.retain(|(t, _), _| *t != tree);
    }

    /// Move one tree's epoch fence *without* discarding its dedup
    /// windows — the promotion path of warm-standby failover.  A
    /// promoted standby continues the crashed primary's job from its
    /// restored checkpoint: the windows' cumulative sequence numbers
    /// are exactly what the senders rebase onto, so clearing them (as
    /// [`Self::begin_epoch`] does for restart-from-scratch recovery)
    /// would force a full replay instead of a bounded one.
    pub fn adopt_epoch(&mut self, tree: TreeId, epoch: u16) {
        let cur = self.tree_epoch(tree);
        assert!(epoch >= cur, "epoch must not regress ({epoch} < {cur})");
        self.epochs.insert(tree, epoch);
    }

    /// Cumulative contiguously-admitted sequence number of one child's
    /// reliable stream (0 when no window exists yet) — what a sender
    /// rebases from after a standby promotion.
    pub fn dedup_cum(&self, tree: TreeId, child: u16) -> u32 {
        self.dedup
            .get(&(tree, child))
            .map_or(0, |w| w.cum_seq())
    }

    /// Serialize one resident tree's complete aggregation state into a
    /// deterministic [`SwitchSnapshot`]: engine core (pacing, EoT
    /// quorum, analyzer/crossbar/scheduler, stats), every FPE table and
    /// BPE region (each its own section, so incremental checkpoints can
    /// ship only dirtied memory), per-child dedup windows, the tree
    /// epoch, and tenant metadata (quota, weight, idle).  `None` when
    /// the tree is not resident.  Static configuration (the
    /// [`SwitchConfig`], intervals, policies) is *not* serialized — a
    /// restore target is built from the same config, and the snapshot
    /// carries only the geometry needed to verify that.
    pub fn snapshot_tree(&self, tree: TreeId) -> Option<SwitchSnapshot> {
        let tenant = self.tenants.get(tree)?;
        let engine = &tenant.engine;
        let mut snap = SwitchSnapshot::new();

        let mut buf = Vec::new();
        codec::put_u32(&mut buf, tree.0);
        codec::put_u16(&mut buf, self.tree_epoch(tree));
        codec::put_u16(&mut buf, tenant.config.children);
        codec::put_u8(&mut buf, snapshot::op_code(tenant.config.op));
        codec::put_u8(&mut buf, tenant.config.parent_port);
        codec::put_u32(&mut buf, tenant.lanes as u32);
        codec::put_u32(&mut buf, self.rel_window.get());
        codec::put_u64(&mut buf, tenant.weight);
        codec::put_u8(&mut buf, tenant.idle as u8);
        match tenant.quota {
            Some(q) => {
                codec::put_u8(&mut buf, 1);
                codec::put_u64(&mut buf, q.fpe_bytes);
                codec::put_u64(&mut buf, q.bpe_bytes);
            }
            None => codec::put_u8(&mut buf, 0),
        }
        codec::put_u64(&mut buf, tenant.fpe_share);
        match tenant.bpe_share {
            Some(s) => {
                codec::put_u8(&mut buf, 1);
                codec::put_u64(&mut buf, s);
            }
            None => codec::put_u8(&mut buf, 0),
        }
        snap.insert(snapshot::SEC_META, buf);

        let mut buf = Vec::new();
        engine.snapshot_write_core(&mut buf);
        snap.insert(snapshot::SEC_ENGINE, buf);

        let mut buf = Vec::new();
        let windows: Vec<(u16, &DedupWindow)> = self
            .dedup
            .iter()
            .filter(|((t, _), _)| *t == tree)
            .map(|((_, c), w)| (*c, w))
            .collect();
        codec::put_u32(&mut buf, windows.len() as u32);
        for (child, w) in windows {
            codec::put_u16(&mut buf, child);
            w.snapshot_write(&mut buf);
        }
        snap.insert(snapshot::SEC_DEDUP, buf);

        for g in 0..engine.n_fpe_groups() {
            let mut buf = Vec::new();
            engine.snapshot_write_fpe(g, &mut buf);
            snap.insert(snapshot::SEC_FPE_BASE + g as u32, buf);
        }
        if engine.n_bpe_regions() > 0 {
            let mut buf = Vec::new();
            engine.snapshot_write_bpe_meta(&mut buf);
            snap.insert(snapshot::SEC_BPE_META, buf);
            for g in 0..engine.n_bpe_regions() {
                let mut buf = Vec::new();
                engine.snapshot_write_bpe_region(g, &mut buf);
                snap.insert(snapshot::SEC_BPE_REGION_BASE + g as u32, buf);
            }
        }
        Some(snap)
    }

    /// Install a [`SwitchSnapshot`] into this switch's *pre-configured*
    /// resident incarnation of the snapshotted tree.  The target must
    /// already hold the tree (same [`TreeConfig`], lane width, memory
    /// shares, and session [`RelWindow`] as the snapshot source) —
    /// restore verifies all of that and rejects mismatches with typed
    /// errors.  On success the switch continues the source's ingest
    /// byte-identically: engine memory, dedup windows, the epoch
    /// register, and tenant metadata are all installed.  On error the
    /// engine may be partially written — the caller must evict the tree
    /// (or re-configure it) rather than ingest into it; the dedup map,
    /// epoch register, and tenant metadata are only committed after
    /// every section has decoded.
    pub fn restore_tree(&mut self, snap: &SwitchSnapshot) -> Result<TreeId, SnapshotError> {
        let meta = snap
            .section(snapshot::SEC_META)
            .ok_or(SnapshotError::Invalid("missing META section"))?;
        let mut cur = SnapCursor::new(meta);
        let tree = TreeId(cur.u32()?);
        let epoch = cur.u16()?;
        let children = cur.u16()?;
        let op = snapshot::op_from_code(cur.u8()?)
            .ok_or(SnapshotError::Invalid("unknown aggregation op"))?;
        let parent_port = cur.u8()?;
        let lanes = cur.u32()? as usize;
        let window = cur.u32()?;
        let weight = cur.u64()?;
        let idle = match cur.u8()? {
            0 => false,
            1 => true,
            _ => return Err(SnapshotError::Invalid("idle flag")),
        };
        let quota = match cur.u8()? {
            0 => None,
            1 => Some(QuotaRequest {
                fpe_bytes: cur.u64()?,
                bpe_bytes: cur.u64()?,
            }),
            _ => return Err(SnapshotError::Invalid("quota flag")),
        };
        let fpe_share = cur.u64()?;
        let bpe_share = match cur.u8()? {
            0 => None,
            1 => Some(cur.u64()?),
            _ => return Err(SnapshotError::Invalid("BPE share flag")),
        };
        cur.finish()?;

        let Some(tenant) = self.tenants.get(tree) else {
            return Err(SnapshotError::Geometry("tree not resident on restore target"));
        };
        if tenant.config.children != children
            || tenant.config.op != op
            || tenant.config.parent_port != parent_port
        {
            return Err(SnapshotError::Geometry("tree configuration"));
        }
        if tenant.lanes != lanes {
            return Err(SnapshotError::Geometry("value lane width"));
        }
        if tenant.quota != quota
            || tenant.fpe_share != fpe_share
            || tenant.bpe_share != bpe_share
        {
            return Err(SnapshotError::Geometry("memory shares"));
        }
        if self.rel_window.get() != window {
            return Err(SnapshotError::Geometry("reliability window"));
        }
        if epoch < self.tree_epoch(tree) {
            return Err(SnapshotError::Invalid("restore would regress the tree epoch"));
        }

        // Decode the dedup windows *before* touching engine memory, so
        // a malformed DEDUP section leaves the target fully intact.
        let sec = snap
            .section(snapshot::SEC_DEDUP)
            .ok_or(SnapshotError::Invalid("missing DEDUP section"))?;
        let mut cur = SnapCursor::new(sec);
        let n = cur.u32()?;
        if n > children as u32 {
            return Err(SnapshotError::Invalid("more dedup windows than children"));
        }
        let mut windows: Vec<(u16, DedupWindow)> = Vec::with_capacity(n as usize);
        let mut last: Option<u16> = None;
        for _ in 0..n {
            let child = cur.u16()?;
            if last.is_some_and(|l| child <= l) {
                return Err(SnapshotError::Invalid(
                    "dedup children not strictly increasing",
                ));
            }
            if child >= children {
                return Err(SnapshotError::Invalid("dedup child beyond fan-in"));
            }
            last = Some(child);
            let w = DedupWindow::snapshot_read(&mut cur)?;
            if w.window_size() != window {
                return Err(SnapshotError::Geometry("reliability window"));
            }
            windows.push((child, w));
        }
        cur.finish()?;

        // Engine core + every FPE table + BPE meta/regions.
        let engine = self.tenants.engine_mut(tree).expect("tenant checked above");
        let sec = snap
            .section(snapshot::SEC_ENGINE)
            .ok_or(SnapshotError::Invalid("missing ENGINE section"))?;
        let mut cur = SnapCursor::new(sec);
        engine.snapshot_read_core(&mut cur)?;
        cur.finish()?;
        for g in 0..engine.n_fpe_groups() {
            let sec = snap
                .section(snapshot::SEC_FPE_BASE + g as u32)
                .ok_or(SnapshotError::Invalid("missing FPE section"))?;
            let mut cur = SnapCursor::new(sec);
            engine.snapshot_read_fpe(g, &mut cur)?;
            cur.finish()?;
        }
        let n_regions = engine.n_bpe_regions();
        if n_regions > 0 {
            let sec = snap
                .section(snapshot::SEC_BPE_META)
                .ok_or(SnapshotError::Invalid("missing BPE meta section"))?;
            let mut cur = SnapCursor::new(sec);
            engine.snapshot_read_bpe_meta(&mut cur)?;
            cur.finish()?;
            for g in 0..n_regions {
                let sec = snap
                    .section(snapshot::SEC_BPE_REGION_BASE + g as u32)
                    .ok_or(SnapshotError::Invalid("missing BPE region section"))?;
                let mut cur = SnapCursor::new(sec);
                engine.snapshot_read_bpe_region(g, &mut cur)?;
                cur.finish()?;
            }
        } else if snap.section(snapshot::SEC_BPE_META).is_some() {
            return Err(SnapshotError::Geometry("BPE presence"));
        }

        // Commit the sequence/fence/metadata state last.
        self.dedup.retain(|(t, _), _| *t != tree);
        for (child, w) in windows {
            self.dedup.insert((tree, child), w);
        }
        self.epochs.insert(tree, epoch);
        self.tenants.set_weight(tree, weight);
        self.tenants.set_idle(tree, idle);
        Ok(tree)
    }

    /// Simulate a switch crash: all soft state dies — aggregation
    /// engines (FPE/BPE contents), tree configuration, dedup windows,
    /// epoch registers, pending sink output.  What survives is what a
    /// real device keeps across a power cycle: the static `cfg`
    /// (hardware shape), the session's `rel_window`/`credit_policy`
    /// (re-pushed control plane would restore them anyway), and the
    /// stale-epoch counters (simulator accounting).  The controller
    /// brings the device back by re-sending Configure and then
    /// [`Self::begin_epoch`] with the bumped epoch.
    pub fn crash(&mut self) {
        self.header_extract = HeaderExtract::new();
        self.forwarding = Forwarding::new();
        self.config_module = ConfigModule::new();
        self.tenants.clear();
        self.lane_width.clear();
        self.dedup.clear();
        self.epochs.clear();
        self.sink.clear();
    }

    pub fn config(&self) -> &SwitchConfig {
        &self.cfg
    }

    /// Apply a Configure packet (§4.2.2).  Memory is re-partitioned
    /// among all configured trees per the active [`MemoryPolicy`]
    /// (even by default, demand-weighted per §7 if hints were
    /// announced); engines are (re)built, so configuration must
    /// precede data for those trees.
    pub fn configure(&mut self, trees: &[TreeConfig]) {
        for t in trees {
            self.lane_width.insert(t.tree, 1);
        }
        self.rebuild_engines(trees);
    }

    /// [`Self::configure`] for trees whose values are W-lane vectors
    /// (`lanes ≥ 1`; 1 is exactly the scalar configuration): every FPE
    /// table and BPE region for the listed trees is built with a
    /// stride-`lanes` value buffer, and ingest goes through the
    /// [`Self::ingest_vector_stream`] family.  Trees configured
    /// earlier keep their own lane widths.
    pub fn configure_vector(&mut self, trees: &[TreeConfig], lanes: usize) {
        assert!(
            (1..=crate::protocol::MAX_LANES).contains(&lanes),
            "lane width {lanes} out of range"
        );
        for t in trees {
            self.lane_width.insert(t.tree, lanes);
        }
        self.rebuild_engines(trees);
    }

    /// Rebuild engines for all configured trees with their new memory
    /// shares (and per-tree lane widths).
    fn rebuild_engines(&mut self, trees: &[TreeConfig]) {
        self.config_module.apply(trees);
        let ids: Vec<TreeId> = self.config_module.tree_ids().collect();
        // A rebuild starts every configured tree's job from scratch, so
        // its reliable sequence spaces restart too — stale windows
        // would silently swallow a fresh stream as "duplicates".
        self.dedup.retain(|(t, _), _| !ids.contains(t));
        for id in ids {
            let tc = self.config_module.get(id).unwrap().clone();
            let fpe_share = self.config_module.memory_share_for(id, self.cfg.fpe_total_mem);
            let bpe_share = self
                .cfg
                .bpe_mem
                .map(|m| self.config_module.memory_share_for(id, m));
            let lanes = *self.lane_width.get(&id).unwrap_or(&1);
            self.forwarding.install_tree_parent(id, tc.parent_port);
            let mut engine =
                TreeEngine::new(&self.cfg, tc.op, tc.children, fpe_share, bpe_share, lanes);
            engine.stats.unconfigured_drops = self.unconfigured.get(&id).copied().unwrap_or(0);
            self.tenants.install_legacy(tc, engine, lanes);
        }
    }

    /// Announce a tree's relative memory demand (application hint, §7
    /// "Memory Utilization"); takes effect at the next `configure`.
    pub fn set_memory_policy(&mut self, policy: crate::switch::config::MemoryPolicy) {
        self.config_module.policy = policy;
    }

    /// Select the ingest execution engine (serial reference or the
    /// group-sharded worker pool); takes effect immediately and does
    /// not change outputs or stats (see `switch::parallel`).
    pub fn set_parallelism(&mut self, parallelism: Parallelism) {
        self.cfg.parallelism = parallelism;
    }

    /// Set a tree's demand weight (used by the Weighted policy).
    pub fn set_tree_weight(&mut self, tree: TreeId, weight: u64) {
        self.config_module.set_weight(tree, weight);
    }

    pub fn n_trees(&self) -> usize {
        self.tenants.len()
    }

    /// Record a packet that arrived for a tree with no resident engine
    /// (not yet configured, or evicted under churn).  A counted drop,
    /// not a panic: under tenant churn this is reachable from the wire.
    fn note_unconfigured_drop(&mut self, tree: TreeId) {
        *self.unconfigured.entry(tree).or_insert(0) += 1;
    }

    /// Packets dropped so far because `tree` had no resident engine.
    /// Survives [`Self::crash`] and engine rebuilds (the count is also
    /// mirrored into the tree's [`SwitchStats`] at engine build).
    pub fn unconfigured_drops(&self, tree: TreeId) -> u64 {
        self.unconfigured.get(&tree).copied().unwrap_or(0)
    }

    /// Record a packet rejected at ingress because its CRC32C trailer
    /// failed verification.  A counted drop, not a panic: corruption on
    /// the wire is reachable by construction, and the reliable layer's
    /// retransmission recovers the payload (the packet is discarded
    /// before dedup admission, so its sequence number stays un-acked).
    pub fn note_corrupt_drop(&mut self, tree: TreeId) {
        *self.corrupt_drops.entry(tree).or_insert(0) += 1;
    }

    /// Packets dropped so far at `tree`'s ingress for CRC mismatch.
    /// Survives [`Self::crash`] (simulator accounting).
    pub fn corrupt_drops(&self, tree: TreeId) -> u64 {
        self.corrupt_drops.get(&tree).copied().unwrap_or(0)
    }

    /// Verify `tree`'s aggregation memory against its per-region audit
    /// digests (FPE tables first, then BPE regions; see
    /// `HashTable::audit`).  `Ok(())` means every resident slot still
    /// matches the history of combines that produced it; a poisoned
    /// bit surfaces as a typed [`IntegrityError::AuditMismatch`] naming
    /// the failing stage, which the framework layer turns into an
    /// epoch-fenced re-run.  An unconfigured tree is itself an error —
    /// auditing memory that does not exist is a caller bug worth
    /// surfacing, not vacuous success.
    pub fn audit_tree(&self, tree: TreeId) -> Result<(), IntegrityError> {
        let Some(engine) = self.tenants.engine(tree) else {
            return Err(IntegrityError::Unconfigured { tree });
        };
        engine
            .audit()
            .map_err(|(stage, expected, computed)| IntegrityError::AuditMismatch {
                tree,
                stage,
                expected,
                computed,
            })
    }

    /// Flip one bit of a value resident in `tree`'s aggregation memory
    /// (fault injection; `seed` picks region, slot, lane, and bit).
    /// Returns `false` when the tree is unconfigured or holds no
    /// entries — nothing to poison.  The damage is silent until
    /// [`Self::audit_tree`] (or a drain-side reducer audit) looks.
    pub fn inject_sram_flip(&mut self, tree: TreeId, seed: u64) -> bool {
        match self.tenants.engine_mut(tree) {
            Some(engine) => engine.poison_sram(seed),
            None => false,
        }
    }

    /// Ingest one aggregation packet for its tree, appending outputs to
    /// a caller-owned (reusable) sink.
    pub fn ingest_into(&mut self, pkt: &AggregationPacket, sink: &mut IngestSink) {
        let Some(engine) = self.tenants.engine_mut(pkt.tree) else {
            self.note_unconfigured_drop(pkt.tree);
            return;
        };
        engine.ingest_pairs(&pkt.pairs, pkt.eot, self.cfg.delays.header_analyzer, sink);
    }

    /// Ingest one W-lane vector aggregation packet for its tree,
    /// appending outputs to a caller-owned (reusable) [`VectorSink`].
    pub fn ingest_vector_packet_into(
        &mut self,
        pkt: &crate::protocol::VectorAggregationPacket,
        sink: &mut VectorSink,
    ) {
        self.ingest_vector_range_for(pkt.tree, &pkt.batch, 0..pkt.batch.len(), pkt.eot, sink);
    }

    /// Admit one reliable packet's `(child, seq, eot)` through its
    /// dedup window.  Returns `(ingest_payload, fire_eot)` — whether
    /// the pairs are new (retransmissions and wire duplicates are
    /// dropped here, before any engine sees them) and whether the
    /// deferred end-of-transmission signal became deliverable — plus
    /// the ack to send back.  Shared by the scalar and vector reliable
    /// entry points so exactly-once semantics cannot drift between
    /// them.
    fn admit_reliable(
        &mut self,
        tree: TreeId,
        rel: crate::protocol::RelHeader,
        eot: bool,
    ) -> (bool, bool, AggAckPacket) {
        let cur_epoch = self.tree_epoch(tree);
        if !self.tenants.contains(tree) {
            // No resident engine: count the drop and ack the current
            // window state without creating one — an evicted tree must
            // not grow fresh dedup state from straggler retransmissions.
            self.note_unconfigured_drop(tree);
            let (cum_seq, credit) = match self.dedup.get(&(tree, rel.child)) {
                Some(w) => (w.cum_seq(), w.credit()),
                None => (0, self.rel_window.get() as u16),
            };
            let ack = AggAckPacket {
                tree,
                child: rel.child,
                epoch: cur_epoch,
                cum_seq,
                credit,
            };
            return (false, false, ack);
        }
        if rel.epoch != cur_epoch {
            // Epoch fence: traffic from a dead incarnation must neither
            // reach an engine nor perturb any window.  The ack restates
            // the current epoch with the (possibly fresh) window state,
            // so a live-but-stale sender learns it must rebase.
            *self.stale_epoch.entry(tree).or_insert(0) += 1;
            let (cum_seq, credit) = match self.dedup.get(&(tree, rel.child)) {
                Some(w) => (w.cum_seq(), w.credit()),
                None => (0, self.rel_window.get() as u16),
            };
            let ack = AggAckPacket {
                tree,
                child: rel.child,
                epoch: cur_epoch,
                cum_seq,
                credit,
            };
            return (false, false, ack);
        }
        let window = self.rel_window;
        let w = self
            .dedup
            .entry((tree, rel.child))
            .or_insert_with(|| DedupWindow::sized(window));
        let (is_new, fire) = match w.offer(rel.seq, eot) {
            Admit::New => (true, w.take_ready_eot()),
            Admit::Duplicate | Admit::OutOfWindow => (false, false),
        };
        let cum_seq = w.cum_seq();
        let mut credit = w.credit();
        if matches!(self.credit_policy, CreditPolicy::Backpressure) {
            if let Some(e) = self.tenants.engine(tree) {
                let (depth, cap) = e.input_queue();
                credit = backpressure_credit(credit, depth, cap);
            }
        }
        if matches!(self.grant_policy, GrantPolicy::WeightedShare) && self.tenants.busy_tenants() > 1
        {
            // Per-tenant weighted credit: an aggressive flooder's acks
            // grant at most its weight share of the window, so it
            // cannot monopolize PE-input FIFO credit while a
            // better-weighted neighbor is active.  With one (or no)
            // active tenant the full window applies — isolation is
            // only throttling when there is someone to isolate.
            let grants = WeightedGrants::new(self.rel_window.get() as u16);
            credit = grants.cap(
                credit,
                self.tenants.weight_of(tree),
                self.tenants.busy_weight(),
            );
        }
        let ack = AggAckPacket {
            tree,
            child: rel.child,
            epoch: cur_epoch,
            cum_seq,
            credit,
        };
        (is_new, fire, ack)
    }

    /// Ingest one batch of reliable aggregation packets (one tree),
    /// exactly-once: every packet passes its `(tree, child)` dedup
    /// window first, admitted chunks run through the configured engine
    /// (serial or sharded — the whole batch goes down the chunk-
    /// sequence path, so a sharded switch shards reliable ingest too),
    /// and one cumulative-ack/credit record per input packet is
    /// returned for the senders.  EoT flags are deferred by the window
    /// until the child's stream prefix is complete, so a flush can
    /// never strand late retransmissions in the tables.
    pub fn ingest_reliable_batch(
        &mut self,
        tree: TreeId,
        pkts: &[&AggregationPacket],
        sink: &mut IngestSink,
    ) -> Vec<AggAckPacket> {
        let mut acks = Vec::with_capacity(pkts.len());
        let mut chunks: Vec<(&[KvPair], bool)> = Vec::with_capacity(pkts.len());
        for pkt in pkts {
            assert_eq!(pkt.tree, tree, "reliable batch must be single-tree");
            let rel = pkt.rel.expect("reliable ingest requires a rel header");
            let (is_new, fire, ack) = self.admit_reliable(tree, rel, pkt.eot);
            if is_new {
                chunks.push((pkt.pairs.as_slice(), fire));
            }
            acks.push(ack);
        }
        if !chunks.is_empty() {
            self.ingest_chunk_seq(tree, &chunks, sink);
        }
        acks
    }

    /// Single-packet reliable ingest — the per-arrival entry point for
    /// the event-driven co-simulation (`framework::transport`), which
    /// reacts to one `NetSim` delivery at a time: identical admission
    /// and engine path to a one-element [`Self::ingest_reliable_batch`],
    /// but with no per-call ack/chunk heap allocation (the chunk
    /// sequence lives on the stack), so the delivery hot loop stays
    /// allocation-free.
    pub fn ingest_reliable_one(
        &mut self,
        tree: TreeId,
        pkt: &AggregationPacket,
        sink: &mut IngestSink,
    ) -> AggAckPacket {
        assert_eq!(pkt.tree, tree, "reliable ingest must be single-tree");
        let rel = pkt.rel.expect("reliable ingest requires a rel header");
        let (is_new, fire, ack) = self.admit_reliable(tree, rel, pkt.eot);
        if is_new {
            self.ingest_chunk_seq(tree, &[(pkt.pairs.as_slice(), fire)], sink);
        }
        ack
    }

    /// The W-lane counterpart of [`Self::ingest_reliable_one`].
    pub fn ingest_vector_reliable_one(
        &mut self,
        tree: TreeId,
        pkt: &crate::protocol::VectorAggregationPacket,
        sink: &mut VectorSink,
    ) -> AggAckPacket {
        assert_eq!(pkt.tree, tree, "reliable ingest must be single-tree");
        let rel = pkt.rel.expect("reliable ingest requires a rel header");
        let (is_new, fire, ack) = self.admit_reliable(tree, rel, pkt.eot);
        if is_new {
            self.ingest_vector_range_for(tree, &pkt.batch, 0..pkt.batch.len(), fire, sink);
        }
        ack
    }

    /// The W-lane counterpart of [`Self::ingest_reliable_batch`]:
    /// admitted vector packets take the serial columnar path (vector
    /// ingest is always serial; see [`Self::ingest_vector_stream_into`]).
    pub fn ingest_vector_reliable_batch(
        &mut self,
        tree: TreeId,
        pkts: &[&crate::protocol::VectorAggregationPacket],
        sink: &mut VectorSink,
    ) -> Vec<AggAckPacket> {
        let mut acks = Vec::with_capacity(pkts.len());
        for pkt in pkts {
            assert_eq!(pkt.tree, tree, "reliable batch must be single-tree");
            let rel = pkt.rel.expect("reliable ingest requires a rel header");
            let (is_new, fire, ack) = self.admit_reliable(tree, rel, pkt.eot);
            if is_new {
                self.ingest_vector_range_for(tree, &pkt.batch, 0..pkt.batch.len(), fire, sink);
            }
            acks.push(ack);
        }
        acks
    }

    /// Aggregate dedup counters over all of `tree`'s child windows.
    pub fn dedup_stats(&self, tree: TreeId) -> DedupStats {
        let mut out = DedupStats::default();
        for ((t, _), w) in &self.dedup {
            if *t == tree {
                let s = w.stats();
                out.admitted += s.admitted;
                out.dup_drops += s.dup_drops;
                out.out_of_window += s.out_of_window;
            }
        }
        out.stale_epoch_drops = self.stale_epoch.get(&tree).copied().unwrap_or(0);
        out.corrupt_drops = self.corrupt_drops.get(&tree).copied().unwrap_or(0);
        out
    }

    /// Ingest one aggregation packet, returning owned output buffers
    /// (compatibility wrapper; hot loops should prefer
    /// [`Self::ingest_into`] with a reused [`IngestSink`]).
    pub fn ingest(&mut self, pkt: &AggregationPacket) -> IngestOutput {
        let mut sink = IngestSink::new();
        self.ingest_into(pkt, &mut sink);
        IngestOutput {
            forwarded: sink.forwarded,
            flushed: (sink.flushes > 0).then_some(sink.flushed),
        }
    }

    /// Capacity of the internal reusable ingest sink — lets tests
    /// assert that the steady-state stream path stops allocating.
    pub fn sink_capacity(&self) -> usize {
        self.sink.capacity()
    }

    /// Convenience: run a whole pair stream (chunked into MTU-sized
    /// packets on the fly) through one tree; EoT is counted once per
    /// `children`, so pass the merged stream of all children — or use
    /// [`Self::ingest_child_streams`].
    pub fn ingest_stream(&mut self, tree: TreeId, op: AggOp, pairs: &[KvPair]) -> Vec<KvPair> {
        let _ = op; // the tree's configured op applies; kept for API compat
        let mut sink = std::mem::take(&mut self.sink);
        sink.clear();
        let children = self.children_of(tree);
        // Merged stream: emit children EoTs by splitting at the end
        // (Theorem 2.1: merging flows preserves the reduction ratio).
        if matches!(self.cfg.parallelism, Parallelism::Serial) {
            // Serial reference: stream the chunks straight through —
            // no chunk list, no per-packet allocation.
            let mut chunks = MtuChunks::new(pairs);
            while let Some((chunk, _)) = chunks.next_chunk() {
                self.ingest_pairs_for(tree, chunk, false, &mut sink);
            }
            for _ in 0..children {
                self.ingest_pairs_for(tree, &[], true, &mut sink);
            }
        } else {
            let empty: &[KvPair] = &[];
            let mut chunk_seq: Vec<(&[KvPair], bool)> = Vec::new();
            let mut chunks = MtuChunks::new(pairs);
            while let Some((chunk, _)) = chunks.next_chunk() {
                chunk_seq.push((chunk, false));
            }
            for _ in 0..children {
                chunk_seq.push((empty, true));
            }
            self.ingest_chunk_seq(tree, &chunk_seq, &mut sink);
        }
        self.finalize(tree);
        let out = sink_to_vec(&sink);
        self.sink = sink;
        out
    }

    /// Ingest per-child streams interleaved round-robin packet-wise —
    /// the many-to-one pattern of Fig. 1.
    pub fn ingest_child_streams(
        &mut self,
        tree: TreeId,
        op: AggOp,
        streams: &[Vec<KvPair>],
    ) -> Vec<KvPair> {
        let _ = op; // the tree's configured op applies; kept for API compat
        let mut sink = std::mem::take(&mut self.sink);
        sink.clear();
        let mut chunkers: Vec<MtuChunks<'_>> =
            streams.iter().map(|s| MtuChunks::new(s)).collect();
        if matches!(self.cfg.parallelism, Parallelism::Serial) {
            // Serial reference: stream the interleaved chunks straight
            // through — no chunk list, no per-packet allocation.
            loop {
                let mut progressed = false;
                for c in chunkers.iter_mut() {
                    if let Some((chunk, last)) = c.next_chunk() {
                        progressed = true;
                        self.ingest_pairs_for(tree, chunk, last, &mut sink);
                    }
                }
                if !progressed {
                    break;
                }
            }
        } else {
            let mut chunk_seq: Vec<(&[KvPair], bool)> = Vec::new();
            loop {
                let mut progressed = false;
                for c in chunkers.iter_mut() {
                    if let Some((chunk, last)) = c.next_chunk() {
                        progressed = true;
                        chunk_seq.push((chunk, last));
                    }
                }
                if !progressed {
                    break;
                }
            }
            self.ingest_chunk_seq(tree, &chunk_seq, &mut sink);
        }
        self.finalize(tree);
        let out = sink_to_vec(&sink);
        self.sink = sink;
        out
    }

    /// Run a whole W-lane vector stream (chunked into per-W MTU-sized
    /// packets on the fly) through one tree, appending to a
    /// caller-owned (reusable) [`VectorSink`] — the vector counterpart
    /// of [`Self::ingest_stream`].  EoT is counted once per child, so
    /// pass the merged stream of all children — or use
    /// [`Self::ingest_vector_child_streams_into`].  Always runs the
    /// serial reference engine.
    pub fn ingest_vector_stream_into(
        &mut self,
        tree: TreeId,
        batch: &VectorBatch,
        sink: &mut VectorSink,
    ) {
        let children = self.children_of(tree);
        let mut chunks = VectorChunks::new(batch);
        while let Some((range, _)) = chunks.next_chunk() {
            self.ingest_vector_range_for(tree, batch, range, false, sink);
        }
        for _ in 0..children {
            self.ingest_vector_range_for(tree, batch, 0..0, true, sink);
        }
        self.finalize(tree);
    }

    /// [`Self::ingest_vector_stream_into`] into a fresh batch
    /// (forwarded stream followed by the end-of-tree flush).
    pub fn ingest_vector_stream(&mut self, tree: TreeId, batch: &VectorBatch) -> VectorBatch {
        let mut sink = VectorSink::new(batch.lanes());
        self.ingest_vector_stream_into(tree, batch, &mut sink);
        vector_sink_to_batch(&sink)
    }

    /// Ingest per-child W-lane streams interleaved round-robin
    /// packet-wise — the many-to-one pattern of Fig. 1, vector
    /// payloads (allreduce fan-in).
    pub fn ingest_vector_child_streams_into(
        &mut self,
        tree: TreeId,
        streams: &[VectorBatch],
        sink: &mut VectorSink,
    ) {
        let mut chunkers: Vec<VectorChunks<'_>> =
            streams.iter().map(VectorChunks::new).collect();
        loop {
            let mut progressed = false;
            for (s, c) in streams.iter().zip(chunkers.iter_mut()) {
                if let Some((range, last)) = c.next_chunk() {
                    progressed = true;
                    self.ingest_vector_range_for(tree, s, range, last, sink);
                }
            }
            if !progressed {
                break;
            }
        }
        self.finalize(tree);
    }

    /// [`Self::ingest_vector_child_streams_into`] into a fresh batch.
    pub fn ingest_vector_child_streams(
        &mut self,
        tree: TreeId,
        streams: &[VectorBatch],
    ) -> VectorBatch {
        let lanes = streams.first().map(|b| b.lanes()).unwrap_or(1);
        let mut sink = VectorSink::new(lanes);
        self.ingest_vector_child_streams_into(tree, streams, &mut sink);
        vector_sink_to_batch(&sink)
    }

    /// Fan-in (EoT quota) for `tree`: the resident tenant's configured
    /// child count, 1 when the tree is unknown (legacy permissive
    /// behavior of the stream helpers).
    fn children_of(&self, tree: TreeId) -> u16 {
        self.tenants.get(tree).map_or(1, |t| t.config.children)
    }

    /// Core columnar ingest: one per-W MTU chunk of one tree's vector
    /// traffic, on the serial reference path.
    fn ingest_vector_range_for(
        &mut self,
        tree: TreeId,
        batch: &VectorBatch,
        range: std::ops::Range<usize>,
        eot: bool,
        sink: &mut VectorSink,
    ) {
        let Some(engine) = self.tenants.engine_mut(tree) else {
            self.note_unconfigured_drop(tree);
            return;
        };
        engine.ingest_vector_range(batch, range, eot, self.cfg.delays.header_analyzer, sink);
    }

    /// Core slice-based ingest (no packet object): one MTU chunk of one
    /// tree's traffic, on the serial reference path.
    fn ingest_pairs_for(
        &mut self,
        tree: TreeId,
        pairs: &[KvPair],
        eot: bool,
        sink: &mut IngestSink,
    ) {
        let Some(engine) = self.tenants.engine_mut(tree) else {
            self.note_unconfigured_drop(tree);
            return;
        };
        engine.ingest_pairs(pairs, eot, self.cfg.delays.header_analyzer, sink);
    }

    /// Sharded-engine ingest of a whole chunk sequence for one tree.
    /// The sharded engine requires the (at most one) end-of-tree flush
    /// to land on the final chunk; sequences that flush mid-stream
    /// silently take the serial loop instead.
    fn ingest_chunk_seq(
        &mut self,
        tree: TreeId,
        chunks: &[(&[KvPair], bool)],
        sink: &mut IngestSink,
    ) {
        let header_delay = self.cfg.delays.header_analyzer;
        let parallelism = self.cfg.parallelism;
        let Some(engine) = self.tenants.engine_mut(tree) else {
            self.note_unconfigured_drop(tree);
            return;
        };
        match parallelism {
            Parallelism::Sharded(n) if !engine.flush_splits_stream(chunks) => {
                engine.ingest_chunks_sharded(chunks, header_delay, n.max(1), sink);
            }
            _ => {
                // Count the silent fallback so benchmarks can detect
                // serial numbers recorded under a sharded config.
                if !matches!(parallelism, Parallelism::Serial) {
                    engine.stats.fallback_serial += 1;
                }
                for &(pairs, eot) in chunks {
                    engine.ingest_pairs(pairs, eot, header_delay, sink);
                }
            }
        }
    }

    /// Recovery fallback: flush `tree`'s resident memory into `sink`
    /// now, as if the last EoT had arrived.  Returns `false` when the
    /// tree has no engine.  Used by the corruption driver when a wire
    /// flip destroyed an EoT bit on an *admitted* (CRC-disabled)
    /// packet, so the normal flush can never fire.
    pub fn force_flush(&mut self, tree: TreeId, sink: &mut IngestSink) -> bool {
        match self.tenants.engine_mut(tree) {
            Some(e) => {
                e.force_flush(sink);
                true
            }
            None => false,
        }
    }

    /// W-lane counterpart of [`Self::force_flush`].
    pub fn force_flush_vector(&mut self, tree: TreeId, sink: &mut VectorSink) -> bool {
        match self.tenants.engine_mut(tree) {
            Some(e) => {
                e.force_flush_vector(sink);
                true
            }
            None => false,
        }
    }

    /// Close output byte accounting (packetization of the out stream).
    pub fn finalize(&mut self, tree: TreeId) {
        if let Some(e) = self.tenants.engine_mut(tree) {
            e.finalize_output_bytes();
        }
    }

    pub fn stats(&self, tree: TreeId) -> Option<&SwitchStats> {
        self.tenants.engine(tree).map(|e| &e.stats)
    }

    /// Earliest simulated instant (NetSim seconds) at which output the
    /// switch has produced for `tree` can legally reach the egress
    /// wire, given the job's ingest began at `start_s`.
    ///
    /// The engine's processing lives in the 200 MHz cycle domain
    /// ([`crate::sim::clock`]): `makespan_cycles` covers datapath work
    /// up to the last ingested packet and `flush_cycles` the key-store
    /// sweep.  Mapping the sum through [`cycles_to_secs`] anchors both
    /// clocks to one time base, so a streaming relay cannot forward a
    /// pair before the cycle-domain switch could have emitted it.
    /// A tree with no engine has done no work: `start_s`.
    pub fn egress_ready_s(&self, tree: TreeId, start_s: f64) -> f64 {
        match self.stats(tree) {
            Some(s) => start_s + cycles_to_secs(s.makespan_cycles + s.flush_cycles),
            None => start_s,
        }
    }

    /// Average measured FPE pair latency in cycles (Table 3 check).
    pub fn avg_fpe_latency(&self, tree: TreeId) -> f64 {
        let e = self.tenants.engine(tree).expect("tree not resident");
        let pairs: u64 = e.fpes.iter().map(|f| f.aggregated + f.inserted + f.evicted).sum();
        let cyc: u64 = e.fpes.iter().map(|f| f.latency_cycles).sum();
        if pairs == 0 {
            0.0
        } else {
            cyc as f64 / pairs as f64
        }
    }

    /// Sum of BPE DRAM commands and stall cycles (overlap diagnostics).
    pub fn bpe_dram_stats(&self, tree: TreeId) -> Option<(u64, Cycles)> {
        self.tenants
            .engine(tree)
            .expect("tree not resident")
            .bpe
            .as_ref()
            .map(|b| b.dram_stats())
    }

    // -----------------------------------------------------------------
    // Multi-tenant serving: incremental admission, eviction, quotas
    // -----------------------------------------------------------------

    /// Select how ack credit is shared among tenants (takes effect
    /// immediately; the default [`GrantPolicy::Uniform`] is the
    /// single-tenant behavior, byte-identical to PR 5).
    pub fn set_grant_policy(&mut self, policy: GrantPolicy) {
        self.grant_policy = policy;
    }

    /// Admit a scalar tree *incrementally* against its memory quota:
    /// no other tenant's engine, dedup window, or epoch register is
    /// touched.  Rejection (typed) is side-effect free.
    pub fn admit_tree(
        &mut self,
        tc: TreeConfig,
        quota: QuotaRequest,
        weight: u64,
    ) -> Result<(), AdmissionError> {
        self.admit_tree_lanes(tc, quota, weight, 1)
    }

    /// [`Self::admit_tree`] for a W-lane vector tree.
    pub fn admit_tree_lanes(
        &mut self,
        tc: TreeConfig,
        quota: QuotaRequest,
        weight: u64,
        lanes: usize,
    ) -> Result<(), AdmissionError> {
        assert!(
            (1..=crate::protocol::MAX_LANES).contains(&lanes),
            "lane width {lanes} out of range"
        );
        let tree = tc.tree;
        let parent_port = tc.parent_port;
        self.tenants.admit(&self.cfg, tc, quota, lanes, weight)?;
        self.lane_width.insert(tree, lanes);
        self.forwarding.install_tree_parent(tree, parent_port);
        // A fresh admission starts a fresh job: any dedup state left
        // over from a previous incarnation of this tree id is stale.
        self.dedup.retain(|(t, _), _| *t != tree);
        if let Some(e) = self.tenants.engine_mut(tree) {
            e.stats.unconfigured_drops = self.unconfigured.get(&tree).copied().unwrap_or(0);
        }
        Ok(())
    }

    /// [`Self::admit_tree`], reclaiming idle tenants' slots when the
    /// quota does not fit as-is.  Returns the residents drained from
    /// each shrunken neighbor — the caller owns software-merging them
    /// into the corresponding tenants' aggregates (they are never
    /// silently dropped).
    pub fn admit_tree_or_reclaim(
        &mut self,
        tc: TreeConfig,
        quota: QuotaRequest,
        weight: u64,
    ) -> Result<Vec<(TreeId, Vec<KvPair>)>, AdmissionError> {
        match self.admit_tree(tc.clone(), quota, weight) {
            Ok(()) => Ok(Vec::new()),
            Err(AdmissionError::QuotaExhausted { .. }) => {
                let spilled = self.tenants.reclaim(
                    &self.cfg,
                    quota.fpe_bytes,
                    self.cfg.bpe_mem.map(|_| quota.bpe_bytes).unwrap_or(0),
                    tc.tree,
                );
                match self.admit_tree(tc, quota, weight) {
                    Ok(()) => Ok(spilled),
                    Err(e) if spilled.is_empty() => Err(e),
                    // Admission still failed but neighbors already
                    // shrank: hand the drained residents to the caller
                    // so nothing is lost (the missing tenant remains
                    // observable via `stats()`/`n_trees()`).
                    Err(_) => Ok(spilled),
                }
            }
            Err(e) => Err(e),
        }
    }

    /// Evict one tenant: its ledger charge is released and its
    /// resident aggregation state drained and returned for software
    /// merge.  Surviving tenants keep FPE/BPE/dedup/epoch state
    /// byte-for-byte; the tree's epoch register survives so a future
    /// re-admission continues the fence (stale stragglers from the
    /// evicted incarnation keep being rejected).
    pub fn evict_tree(&mut self, tree: TreeId) -> Option<EvictedResidents> {
        let out = self.tenants.evict(tree)?;
        self.lane_width.remove(&tree);
        self.config_module.remove(tree);
        self.dedup.retain(|(t, _), _| *t != tree);
        Some(out)
    }

    /// Mark a tenant idle (between jobs) or busy.  Idle scalar tenants
    /// are eligible for elastic reclamation and do not count toward
    /// weighted grant shares.
    pub fn set_tenant_idle(&mut self, tree: TreeId, idle: bool) {
        self.tenants.set_idle(tree, idle);
    }

    /// Set a tenant's scheduling weight (weighted grant shares).
    pub fn set_tenant_weight(&mut self, tree: TreeId, weight: u64) {
        self.tenants.set_weight(tree, weight);
    }

    /// Grow a reclaimed tenant back toward its quota if headroom
    /// exists; returns drained residents (normally empty, as regrow
    /// runs between jobs) or `None` when nothing changed.
    pub fn regrow_tenant(&mut self, tree: TreeId) -> Option<Vec<KvPair>> {
        self.tenants.regrow(&self.cfg, tree)
    }

    /// Free (unreserved) FPE/BPE bytes in the quota ledger.
    pub fn quota_free(&self) -> (u64, u64) {
        (
            self.tenants.free_fpe(&self.cfg),
            self.tenants.free_bpe(&self.cfg),
        )
    }

    /// Validating [`Self::configure`]: rejects (typed, side-effect
    /// free) any static split that would round a listed tree down to
    /// zero FPE/BPE slots in its widest key group.  The legacy
    /// [`Self::configure`] stays permissive — degenerate floor-sized
    /// tables are still legal there because downscaled smoke configs
    /// rely on them — so validation is strictly opt-in.
    pub fn try_configure(&mut self, trees: &[TreeConfig]) -> Result<(), AdmissionError> {
        self.validate_static_shares(trees, 1)?;
        self.configure(trees);
        Ok(())
    }

    /// Validating [`Self::configure_vector`].
    pub fn try_configure_vector(
        &mut self,
        trees: &[TreeConfig],
        lanes: usize,
    ) -> Result<(), AdmissionError> {
        self.validate_static_shares(trees, lanes)?;
        self.configure_vector(trees, lanes);
        Ok(())
    }

    /// Check the post-apply static split for zero-capacity rounding
    /// without mutating the live config module.
    fn validate_static_shares(
        &self,
        trees: &[TreeConfig],
        lanes: usize,
    ) -> Result<(), AdmissionError> {
        let mut cm = self.config_module.clone();
        cm.apply(trees);
        let ids: Vec<TreeId> = cm.tree_ids().collect();
        for id in ids {
            let lanes_for = if trees.iter().any(|t| t.tree == id) {
                lanes
            } else {
                *self.lane_width.get(&id).unwrap_or(&1)
            };
            let min = self.cfg.min_fpe_share(lanes_for);
            let share = cm.memory_share_for(id, self.cfg.fpe_total_mem);
            if share < min {
                return Err(AdmissionError::ZeroCapacity {
                    tree: id,
                    stage: "FPE",
                    share,
                    min,
                });
            }
            if let Some(m) = self.cfg.bpe_mem {
                let share = cm.memory_share_for(id, m);
                if share < min {
                    return Err(AdmissionError::ZeroCapacity {
                        tree: id,
                        stage: "BPE",
                        share,
                        min,
                    });
                }
            }
        }
        Ok(())
    }
}

/// Concatenate a sink's stream + flush output (flushes only happen
/// after the final EoT, so this preserves emission order).
fn sink_to_vec(sink: &IngestSink) -> Vec<KvPair> {
    let mut out = Vec::with_capacity(sink.forwarded.len() + sink.flushed.len());
    out.extend_from_slice(&sink.forwarded);
    out.extend_from_slice(&sink.flushed);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::packet::TreeConfig;
    use crate::util::rng::Pcg32;

    fn configured_switch(fpe_mem: u64, bpe_mem: Option<u64>, children: u16) -> SwitchAggSwitch {
        let cfg = SwitchConfig::scaled(fpe_mem, bpe_mem);
        let mut sw = SwitchAggSwitch::new(cfg);
        sw.configure(&[TreeConfig {
            tree: TreeId(1),
            children,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        sw
    }

    fn pairs(n: usize, distinct: u64, seed: u64) -> Vec<KvPair> {
        let mut rng = Pcg32::new(seed);
        (0..n)
            .map(|_| {
                let id = rng.gen_range_u64(distinct);
                KvPair::new(Key::from_id(id, 16 + (id % 49) as usize), 1)
            })
            .collect()
    }

    #[test]
    fn sum_is_conserved_through_the_switch() {
        let mut sw = configured_switch(64 << 10, Some(1 << 20), 1);
        let input = pairs(20_000, 500, 42);
        let want: Value = input.iter().map(|p| p.value).sum();
        let out = sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
        let got: Value = out.iter().map(|p| p.value).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn keys_fully_aggregated_when_memory_sufficient() {
        let mut sw = configured_switch(4 << 20, Some(8 << 20), 1);
        let input = pairs(10_000, 100, 7);
        let out = sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
        // Every distinct key appears exactly once in the output.
        let mut seen = std::collections::HashMap::new();
        for p in &out {
            *seen.entry(p.key).or_insert(0u32) += 1;
        }
        assert!(seen.values().all(|&c| c == 1), "duplicate keys in output");
        assert_eq!(seen.len() as u64, 100);
        let s = sw.stats(TreeId(1)).unwrap();
        assert!(s.reduction_ratio() > 0.9, "r={}", s.reduction_ratio());
    }

    #[test]
    fn small_memory_reduces_reduction_ratio() {
        let big = {
            let mut sw = configured_switch(4 << 20, None, 1);
            sw.ingest_stream(TreeId(1), AggOp::Sum, &pairs(50_000, 20_000, 3));
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        let small = {
            let mut sw = configured_switch(16 << 10, None, 1);
            sw.ingest_stream(TreeId(1), AggOp::Sum, &pairs(50_000, 20_000, 3));
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        assert!(big > small, "big={big} small={small}");
    }

    #[test]
    fn multilevel_beats_single_level() {
        let input = pairs(60_000, 30_000, 9);
        let single = {
            let mut sw = configured_switch(32 << 10, None, 1);
            sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        let multi = {
            let mut sw = configured_switch(32 << 10, Some(4 << 20), 1);
            sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        assert!(multi > single + 0.2, "multi={multi} single={single}");
    }

    #[test]
    fn eot_from_all_children_triggers_flush() {
        let mut sw = configured_switch(1 << 20, Some(1 << 20), 3);
        let streams: Vec<Vec<KvPair>> =
            (0..3).map(|i| pairs(1000, 50, i as u64)).collect();
        let out = sw.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
        let s = sw.stats(TreeId(1)).unwrap();
        assert!(s.pairs_out_flush > 0);
        assert!(s.packets_in > 0);
        let want: Value = streams.iter().flatten().map(|p| p.value).sum();
        let got: Value = out.iter().map(|p| p.value).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn chunked_stream_ingest_matches_packetized_ingest() {
        // The zero-copy stream path must produce byte-for-byte the
        // same outputs and stats as ingesting materialized packets.
        let input = pairs(5_000, 700, 21);
        let mut chunked = configured_switch(16 << 10, Some(256 << 10), 1);
        let out_chunked = chunked.ingest_stream(TreeId(1), AggOp::Sum, &input);

        let mut packetized = configured_switch(16 << 10, Some(256 << 10), 1);
        let pkts = AggregationPacket::pack_stream(TreeId(1), AggOp::Sum, &input, false);
        let mut sink = IngestSink::new();
        for pkt in &pkts {
            packetized.ingest_into(pkt, &mut sink);
        }
        let eot = AggregationPacket {
            tree: TreeId(1),
            op: AggOp::Sum,
            eot: true,
            rel: None,
            pairs: vec![],
        };
        packetized.ingest_into(&eot, &mut sink);
        packetized.finalize(TreeId(1));
        let out_packetized = sink_to_vec(&sink);

        assert_eq!(out_chunked, out_packetized);
        let a = chunked.stats(TreeId(1)).unwrap();
        let b = packetized.stats(TreeId(1)).unwrap();
        assert_eq!((a.packets_in, a.bytes_in, a.bytes_out), (b.packets_in, b.bytes_in, b.bytes_out));
    }

    #[test]
    fn ingest_into_matches_ingest_wrapper() {
        let input = pairs(3_000, 200, 33);
        let pkts = AggregationPacket::pack_stream(TreeId(1), AggOp::Sum, &input, true);
        let mut a = configured_switch(16 << 10, Some(256 << 10), 1);
        let mut b = configured_switch(16 << 10, Some(256 << 10), 1);
        let mut sink = IngestSink::new();
        let mut via_wrapper: Vec<KvPair> = Vec::new();
        for pkt in &pkts {
            let r = a.ingest(pkt);
            via_wrapper.extend(r.forwarded);
            if let Some(f) = r.flushed {
                via_wrapper.extend(f);
            }
            b.ingest_into(pkt, &mut sink);
        }
        let via_sink = sink_to_vec(&sink);
        assert_eq!(via_wrapper, via_sink);
        assert_eq!(sink.flushes, 1);
    }

    #[test]
    fn fifo_full_ratio_is_small_at_line_rate() {
        let mut sw = configured_switch(256 << 10, Some(4 << 20), 1);
        let input = pairs(100_000, 50_000, 11);
        sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
        let s = sw.stats(TreeId(1)).unwrap();
        assert!(s.fifo_writes >= 100_000);
        assert!(
            s.fifo_full_ratio() < 0.01,
            "full ratio {} too high",
            s.fifo_full_ratio()
        );
    }

    #[test]
    fn two_trees_split_memory() {
        let cfg = SwitchConfig::scaled(64 << 10, None);
        let mut sw = SwitchAggSwitch::new(cfg);
        let mk = |id| TreeConfig {
            tree: TreeId(id),
            children: 1,
            parent_port: 0,
            op: AggOp::Sum,
        };
        sw.configure(&[mk(1), mk(2)]);
        assert_eq!(sw.n_trees(), 2);
        let input = pairs(30_000, 10_000, 5);
        let r2trees = {
            sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        let mut solo = SwitchAggSwitch::new(SwitchConfig::scaled(64 << 10, None));
        solo.configure(&[mk(1)]);
        solo.ingest_stream(TreeId(1), AggOp::Sum, &input);
        let r1tree = solo.stats(TreeId(1)).unwrap().reduction_ratio();
        assert!(
            r1tree > r2trees,
            "memory halving should hurt: solo={r1tree} shared={r2trees}"
        );
    }

    #[test]
    fn sharded_ingest_matches_serial_exactly() {
        // Same streams through the serial reference and the sharded
        // engine: outputs and every stat must be byte-identical.
        let streams: Vec<Vec<KvPair>> = (0..3).map(|i| pairs(4_000, 700, 11 + i)).collect();
        let mut serial = configured_switch(16 << 10, Some(256 << 10), 3);
        let out_serial = serial.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
        for shards in [1usize, 2, 4, 8] {
            let mut sharded = configured_switch(16 << 10, Some(256 << 10), 3);
            sharded.set_parallelism(crate::switch::parallel::Parallelism::Sharded(shards));
            let out_sharded = sharded.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
            assert_eq!(out_sharded, out_serial, "{shards} shards");
            let a = serial.stats(TreeId(1)).unwrap();
            let b = sharded.stats(TreeId(1)).unwrap();
            assert_eq!(
                format!("{a:?}"),
                format!("{b:?}"),
                "stats diverged at {shards} shards"
            );
            assert_eq!(
                serial.avg_fpe_latency(TreeId(1)),
                sharded.avg_fpe_latency(TreeId(1))
            );
            assert_eq!(
                serial.bpe_dram_stats(TreeId(1)),
                sharded.bpe_dram_stats(TreeId(1))
            );
        }
    }

    #[test]
    fn sharded_ingest_without_bpe_matches_serial() {
        let input = pairs(8_000, 3_000, 77);
        let mut serial = configured_switch(8 << 10, None, 1);
        let out_serial = serial.ingest_stream(TreeId(1), AggOp::Sum, &input);
        let mut sharded = configured_switch(8 << 10, None, 1);
        sharded.set_parallelism(crate::switch::parallel::Parallelism::Sharded(4));
        let out_sharded = sharded.ingest_stream(TreeId(1), AggOp::Sum, &input);
        assert_eq!(out_sharded, out_serial);
        let a = serial.stats(TreeId(1)).unwrap();
        let b = sharded.stats(TreeId(1)).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn unconfigured_tree_ingest_is_a_counted_drop() {
        // Regression: this used to panic ("tree {} not configured"),
        // which is reachable from the wire under tenant churn — data
        // racing ahead of Configure, or stragglers after an eviction.
        let mut sw = SwitchAggSwitch::new(SwitchConfig::default());
        let pkt = AggregationPacket {
            tree: TreeId(9),
            op: AggOp::Sum,
            eot: false,
            rel: None,
            pairs: pairs(10, 10, 1),
        };
        let out = sw.ingest(&pkt);
        assert!(out.forwarded.is_empty() && out.flushed.is_none());
        assert_eq!(sw.unconfigured_drops(TreeId(9)), 1);
        // A second drop accumulates; other trees are untouched.
        sw.ingest(&pkt);
        assert_eq!(sw.unconfigured_drops(TreeId(9)), 2);
        assert_eq!(sw.unconfigured_drops(TreeId(1)), 0);
        // Configuring the tree afterwards seeds the count into its
        // per-tree stats and resumes normal ingest.
        sw.configure(&[TreeConfig {
            tree: TreeId(9),
            children: 1,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        assert_eq!(sw.stats(TreeId(9)).unwrap().unconfigured_drops, 2);
        let out = sw.ingest(&pkt);
        assert!(out.flushed.is_none());
        assert_eq!(sw.stats(TreeId(9)).unwrap().pairs_in, 10);
    }

    #[test]
    fn unconfigured_reliable_ingest_acks_without_creating_windows() {
        // A reliable straggler for an evicted/unknown tree is counted
        // and dropped, acked from existing window state, and must not
        // grow fresh dedup state.
        let mut sw = SwitchAggSwitch::new(SwitchConfig::default());
        let mut pkt = AggregationPacket {
            tree: TreeId(9),
            op: AggOp::Sum,
            eot: false,
            rel: Some(crate::protocol::RelHeader {
                child: 0,
                epoch: 0,
                seq: 1,
            }),
            pairs: pairs(5, 5, 2),
        };
        let mut sink = IngestSink::new();
        let ack = sw.ingest_reliable_one(TreeId(9), &pkt, &mut sink);
        assert_eq!(ack.cum_seq, 0, "nothing admitted");
        assert_eq!(sw.unconfigured_drops(TreeId(9)), 1);
        assert_eq!(sw.dedup_stats(TreeId(9)).admitted, 0);
        assert!(sink.forwarded.is_empty() && sink.flushes == 0);
        // EoT variant too: no deferred flush may fire later.
        pkt.eot = true;
        let ack = sw.ingest_reliable_one(TreeId(9), &pkt, &mut sink);
        assert_eq!(ack.cum_seq, 0);
        assert_eq!(sw.unconfigured_drops(TreeId(9)), 2);
    }

    /// Packetize a stream with reliability records (child, seq 1..).
    fn rel_packets(tree: TreeId, child: u16, pairs: &[KvPair]) -> Vec<AggregationPacket> {
        let mut pkts = AggregationPacket::pack_stream(tree, AggOp::Sum, pairs, true);
        for (i, p) in pkts.iter_mut().enumerate() {
            p.rel = Some(crate::protocol::RelHeader {
                child,
                epoch: 0,
                seq: i as u32 + 1,
            });
        }
        pkts
    }

    #[test]
    fn reliable_ingest_dedups_retransmissions() {
        let mut sw = configured_switch(16 << 10, Some(256 << 10), 1);
        let input = pairs(3_000, 500, 99);
        let want: Value = input.iter().map(|p| p.value).sum();
        let pkts = rel_packets(TreeId(1), 0, &input);
        let refs: Vec<&AggregationPacket> = pkts.iter().collect();
        let mut sink = IngestSink::new();
        let acks = sw.ingest_reliable_batch(TreeId(1), &refs, &mut sink);
        assert_eq!(acks.len(), pkts.len());
        assert_eq!(acks.last().unwrap().cum_seq, pkts.len() as u32);
        assert_eq!(sink.flushes, 1, "single child: EoT flushes once");
        let delivered = (sink.forwarded.len(), sink.flushed.len());
        let got: Value = sink_to_vec(&sink).iter().map(|p| p.value).sum();
        assert_eq!(got, want);

        // Retransmit the whole stream: every packet is a duplicate —
        // nothing reaches the engines, outputs and stats are unchanged.
        let stats_before = format!("{:?}", sw.stats(TreeId(1)).unwrap());
        let acks2 = sw.ingest_reliable_batch(TreeId(1), &refs, &mut sink);
        assert_eq!(acks2.last().unwrap().cum_seq, pkts.len() as u32);
        assert_eq!((sink.forwarded.len(), sink.flushed.len()), delivered);
        assert_eq!(format!("{:?}", sw.stats(TreeId(1)).unwrap()), stats_before);
        let d = sw.dedup_stats(TreeId(1));
        assert_eq!(d.admitted, pkts.len() as u64);
        assert_eq!(d.dup_drops, pkts.len() as u64);
    }

    #[test]
    fn reliable_one_matches_reliable_batch() {
        // The per-arrival entry point must be byte-identical to a
        // one-element batch: same acks, same outputs, same stats.
        let streams: Vec<Vec<KvPair>> = (0..2).map(|i| pairs(1_500, 200, 60 + i)).collect();
        let mut batch_sw = configured_switch(16 << 10, Some(256 << 10), 2);
        let mut one_sw = configured_switch(16 << 10, Some(256 << 10), 2);
        let mut batch_sink = IngestSink::new();
        let mut one_sink = IngestSink::new();
        for (c, s) in streams.iter().enumerate() {
            let pkts = rel_packets(TreeId(1), c as u16, s);
            for pkt in &pkts {
                let a = batch_sw.ingest_reliable_batch(TreeId(1), &[pkt], &mut batch_sink);
                let b = one_sw.ingest_reliable_one(TreeId(1), pkt, &mut one_sink);
                assert_eq!(a[0], b);
            }
        }
        assert_eq!(batch_sink.flushes, one_sink.flushes);
        assert_eq!(sink_to_vec(&batch_sink), sink_to_vec(&one_sink));
        batch_sw.finalize(TreeId(1));
        one_sw.finalize(TreeId(1));
        assert_eq!(
            format!("{:?}", batch_sw.stats(TreeId(1)).unwrap()),
            format!("{:?}", one_sw.stats(TreeId(1)).unwrap())
        );
        assert_eq!(batch_sw.dedup_stats(TreeId(1)), one_sw.dedup_stats(TreeId(1)));
    }

    #[test]
    fn reliable_ingest_defers_eot_across_reordering() {
        // Deliver each child's packets in reverse order: the EoT
        // packet arrives first, so the flush must wait until the
        // window below it fills — and fire exactly once per tree.
        let mut sw = configured_switch(64 << 10, Some(1 << 20), 2);
        let streams: Vec<Vec<KvPair>> = (0..2).map(|i| pairs(2_000, 300, 7 + i)).collect();
        let want: Value = streams.iter().flatten().map(|p| p.value).sum();
        let mut sink = IngestSink::new();
        for (c, s) in streams.iter().enumerate() {
            let pkts = rel_packets(TreeId(1), c as u16, s);
            let refs: Vec<&AggregationPacket> = pkts.iter().rev().collect();
            sw.ingest_reliable_batch(TreeId(1), &refs, &mut sink);
        }
        assert_eq!(sink.flushes, 1);
        let got: Value = sink_to_vec(&sink).iter().map(|p| p.value).sum();
        assert_eq!(got, want);
        assert_eq!(sw.dedup_stats(TreeId(1)).dup_drops, 0);
    }

    #[test]
    fn reconfigure_resets_reliable_sequence_spaces() {
        // Regression: a second job on a reconfigured tree restarts its
        // seq space at 1 — stale dedup windows must not swallow the
        // fresh stream as duplicates.
        let mut sw = configured_switch(64 << 10, Some(1 << 20), 1);
        let input = pairs(500, 80, 1);
        let want: Value = input.iter().map(|p| p.value).sum();
        let pkts = rel_packets(TreeId(1), 0, &input);
        let refs: Vec<&AggregationPacket> = pkts.iter().collect();
        let mut sink = IngestSink::new();
        sw.ingest_reliable_batch(TreeId(1), &refs, &mut sink);
        assert_eq!(sink.flushes, 1);

        // Reconfigure the same tree: fresh job, fresh seq space.
        sw.configure(&[TreeConfig {
            tree: TreeId(1),
            children: 1,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        let mut sink2 = IngestSink::new();
        let acks = sw.ingest_reliable_batch(TreeId(1), &refs, &mut sink2);
        assert_eq!(sink2.flushes, 1, "second job must flush again");
        assert_eq!(acks.last().unwrap().cum_seq, pkts.len() as u32);
        let got: Value = sink_to_vec(&sink2).iter().map(|p| p.value).sum();
        assert_eq!(got, want, "second job must admit the full stream");
    }

    #[test]
    fn fallback_serial_counter_fires_on_mid_stream_flush() {
        // children=1 with two EoT-carrying streams: the first stream's
        // flush splits the chunk sequence, so a sharded switch must
        // take (and now count) the serial fallback.
        let streams: Vec<Vec<KvPair>> = (0..2).map(|i| pairs(1_000, 100, 40 + i)).collect();
        let mut sharded = configured_switch(16 << 10, Some(256 << 10), 1);
        sharded.set_parallelism(crate::switch::parallel::Parallelism::Sharded(4));
        sharded.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
        assert!(
            sharded.stats(TreeId(1)).unwrap().fallback_serial > 0,
            "mid-stream flush must be recorded as a serial fallback"
        );

        // A clean end-of-stream flush stays on the sharded engine.
        let mut clean = configured_switch(16 << 10, Some(256 << 10), 2);
        clean.set_parallelism(crate::switch::parallel::Parallelism::Sharded(4));
        clean.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
        assert_eq!(clean.stats(TreeId(1)).unwrap().fallback_serial, 0);

        // The serial reference never counts fallbacks.
        let mut serial = configured_switch(16 << 10, Some(256 << 10), 1);
        serial.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
        assert_eq!(serial.stats(TreeId(1)).unwrap().fallback_serial, 0);
    }

    fn configured_vector_switch(
        fpe_mem: u64,
        bpe_mem: Option<u64>,
        children: u16,
        lanes: usize,
    ) -> SwitchAggSwitch {
        let cfg = SwitchConfig::scaled(fpe_mem, bpe_mem);
        let mut sw = SwitchAggSwitch::new(cfg);
        sw.configure_vector(
            &[TreeConfig {
                tree: TreeId(1),
                children,
                parent_port: 0,
                op: AggOp::Sum,
            }],
            lanes,
        );
        sw
    }

    fn vector_streams(
        n_streams: usize,
        n: usize,
        distinct: u64,
        lanes: usize,
        seed: u64,
    ) -> Vec<VectorBatch> {
        let mut rng = Pcg32::new(seed);
        (0..n_streams)
            .map(|_| {
                let mut b = VectorBatch::new(lanes);
                let mut vals: Vec<Value> = vec![0; lanes];
                for _ in 0..n {
                    let id = rng.gen_range_u64(distinct);
                    for (l, v) in vals.iter_mut().enumerate() {
                        *v = (id % 7) as i64 + l as i64 - 3;
                    }
                    b.push(Key::from_id(id, 16 + (id % 49) as usize), &vals);
                }
                b
            })
            .collect()
    }

    #[test]
    fn vector_w1_ingest_is_byte_identical_to_scalar() {
        // The degenerate 1-lane vector path against the scalar path on
        // the same stream: outputs, stats, and DRAM counters must all
        // be byte-identical.
        let input = pairs(8_000, 900, 55);
        let mut scalar = configured_switch(16 << 10, Some(256 << 10), 1);
        let out_scalar = scalar.ingest_stream(TreeId(1), AggOp::Sum, &input);

        let mut vector = configured_vector_switch(16 << 10, Some(256 << 10), 1, 1);
        let batch = VectorBatch::from_pairs(&input);
        let out_vector = vector.ingest_vector_stream(TreeId(1), &batch);

        assert_eq!(out_vector.to_pairs(), out_scalar);
        let a = scalar.stats(TreeId(1)).unwrap();
        let b = vector.stats(TreeId(1)).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(
            scalar.bpe_dram_stats(TreeId(1)),
            vector.bpe_dram_stats(TreeId(1))
        );
        assert_eq!(
            scalar.avg_fpe_latency(TreeId(1)),
            vector.avg_fpe_latency(TreeId(1))
        );
    }

    #[test]
    fn vector_sum_is_conserved_lane_wise() {
        let lanes = 8;
        let streams = vector_streams(3, 2_000, 400, lanes, 77);
        let mut want = vec![0i64; lanes];
        for s in &streams {
            for (_, ls) in s.iter() {
                for (w, v) in want.iter_mut().zip(ls) {
                    *w += v;
                }
            }
        }
        let mut sw = configured_vector_switch(32 << 10, Some(1 << 20), 3, lanes);
        let out = sw.ingest_vector_child_streams(TreeId(1), &streams);
        let mut got = vec![0i64; lanes];
        for (_, ls) in out.iter() {
            for (g, v) in got.iter_mut().zip(ls) {
                *g += v;
            }
        }
        assert_eq!(got, want);
        let s = sw.stats(TreeId(1)).unwrap();
        assert_eq!(s.pairs_in, 6_000);
        assert!(s.reduction_ratio() > 0.0, "r={}", s.reduction_ratio());
    }

    #[test]
    fn vector_keys_fully_aggregated_when_memory_sufficient() {
        let lanes = 16;
        let streams = vector_streams(2, 3_000, 100, lanes, 9);
        let mut sw = configured_vector_switch(4 << 20, Some(8 << 20), 2, lanes);
        let out = sw.ingest_vector_child_streams(TreeId(1), &streams);
        let mut seen = std::collections::HashMap::new();
        for (k, _) in out.iter() {
            *seen.entry(*k).or_insert(0u32) += 1;
        }
        assert!(seen.values().all(|&c| c == 1), "duplicate keys in output");
        assert_eq!(seen.len(), 100);
        let s = sw.stats(TreeId(1)).unwrap();
        assert!(s.reduction_ratio() > 0.9, "r={}", s.reduction_ratio());
    }

    #[test]
    fn vector_sink_reuse_stops_allocating() {
        let lanes = 4;
        let streams = vector_streams(1, 1_500, 300, lanes, 13);
        let mut sw = configured_vector_switch(16 << 10, Some(256 << 10), 1, lanes);
        let mut sink = VectorSink::new(lanes);
        sw.ingest_vector_stream_into(TreeId(1), &streams[0], &mut sink);
        let warm = sink.capacity();
        for _ in 0..3 {
            sink.clear();
            sw.ingest_vector_stream_into(TreeId(1), &streams[0], &mut sink);
        }
        assert_eq!(sink.capacity(), warm, "steady-state vector ingest must not grow buffers");
    }

    #[test]
    #[should_panic(expected = "scalar ingest on a tree configured")]
    fn scalar_ingest_on_vector_tree_panics() {
        let mut sw = configured_vector_switch(16 << 10, None, 1, 8);
        sw.ingest_stream(TreeId(1), AggOp::Sum, &pairs(10, 5, 1));
    }

    #[test]
    #[should_panic(expected = "lane width does not match")]
    fn mismatched_lane_width_panics() {
        let mut sw = configured_vector_switch(16 << 10, None, 1, 8);
        let streams = vector_streams(1, 10, 5, 4, 1);
        sw.ingest_vector_stream(TreeId(1), &streams[0]);
    }

    /// Re-stamp a reliable stream's packets with a new epoch.
    fn restamp_epoch(pkts: &mut [AggregationPacket], epoch: u16) {
        for p in pkts.iter_mut() {
            p.rel.as_mut().unwrap().epoch = epoch;
        }
    }

    #[test]
    fn stale_epoch_retransmission_is_fenced_not_double_counted() {
        // Crash + restart mid-stream: the replay under the new epoch
        // must produce exactly the fault-free aggregate even while
        // old-incarnation retransmissions keep arriving.
        let tree = TreeId(1);
        let input = pairs(2_000, 400, 7);
        let want: Value = input.iter().map(|p| p.value).sum();
        let mut pkts = rel_packets(tree, 0, &input);

        let mut sw = configured_switch(16 << 10, Some(256 << 10), 1);
        let mut sink = IngestSink::new();
        // Epoch 0: half the stream lands, then the switch dies.
        let half = pkts.len() / 2;
        for p in &pkts[..half] {
            sw.ingest_reliable_one(tree, p, &mut sink);
        }
        sw.crash();
        assert_eq!(sw.n_trees(), 0, "crash loses all tree state");

        // Controller re-pushes Configure, then fences epoch 1.
        sw.configure(&[TreeConfig {
            tree,
            children: 1,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        sw.begin_epoch(tree, 1);
        assert_eq!(sw.tree_epoch(tree), 1);
        sink.clear();

        // A straggling epoch-0 retransmission arrives first: fenced —
        // no engine state, no dedup window, but the ack tells the
        // sender the current epoch.
        let ack = sw.ingest_reliable_one(tree, &pkts[0], &mut sink);
        assert_eq!(ack.epoch, 1);
        assert_eq!(ack.cum_seq, 0, "stale packet admitted nothing");
        assert_eq!(sw.dedup_stats(tree).stale_epoch_drops, 1);
        assert_eq!(sw.dedup_stats(tree).admitted, 0);

        // The rebased sender replays the whole stream under epoch 1,
        // with a stale duplicate interleaved mid-replay.
        restamp_epoch(&mut pkts, 1);
        for (i, p) in pkts.iter().enumerate() {
            sw.ingest_reliable_one(tree, p, &mut sink);
            if i == half {
                let mut stale = pkts[10].clone();
                stale.rel.as_mut().unwrap().epoch = 0;
                sw.ingest_reliable_one(tree, &stale, &mut sink);
            }
        }
        assert_eq!(sink.flushes, 1, "EoT fires once under the new epoch");
        let got: Value = sink_to_vec(&sink).iter().map(|p| p.value).sum();
        assert_eq!(got, want, "byte-identical to the fault-free aggregate");
        let d = sw.dedup_stats(tree);
        assert_eq!(d.stale_epoch_drops, 2, "both stale packets fenced");
        assert_eq!(d.admitted, pkts.len() as u64);
        assert_eq!(d.dup_drops, 0, "stale packets never reach a window");
    }

    #[test]
    #[should_panic(expected = "epoch must not regress")]
    fn epoch_regression_panics() {
        let mut sw = configured_switch(16 << 10, None, 1);
        sw.begin_epoch(TreeId(1), 3);
        sw.begin_epoch(TreeId(1), 2);
    }

    fn tc(id: u32, children: u16) -> TreeConfig {
        TreeConfig {
            tree: TreeId(id),
            children,
            parent_port: 0,
            op: AggOp::Sum,
        }
    }

    #[test]
    fn incremental_admission_preserves_neighbor_state_byte_for_byte() {
        // A resident tenant's mid-stream engine state, stats, and dedup
        // windows must be untouched by a neighbor's admission and
        // eviction (the legacy configure() path wipes everything; the
        // quota path must not).
        let cfg = SwitchConfig::scaled(64 << 10, Some(1 << 20));
        let q = QuotaRequest::even_split(&cfg, 4);
        let mut sw = SwitchAggSwitch::new(cfg);
        sw.admit_tree(tc(1, 1), q, 1).unwrap();

        // Park mid-stream state: pairs ingested, no EoT yet.
        let input = pairs(4_000, 900, 5);
        let pkts = rel_packets(TreeId(1), 0, &input);
        let refs: Vec<&AggregationPacket> = pkts.iter().collect();
        let mut sink = IngestSink::new();
        // Hold back the final (EoT) packet so the tree stays open.
        let acks = sw.ingest_reliable_batch(TreeId(1), &refs[..refs.len() - 1], &mut sink);
        assert_eq!(acks.len(), refs.len() - 1);
        let stats_mid = format!("{:?}", sw.stats(TreeId(1)).unwrap());
        let dedup_mid = format!("{:?}", sw.dedup_stats(TreeId(1)));

        // Neighbor churn: admit two tenants, evict one.
        sw.admit_tree(tc(2, 2), q, 1).unwrap();
        sw.admit_tree(tc(3, 2), q, 1).unwrap();
        let res = sw.evict_tree(TreeId(2)).unwrap();
        assert!(res.is_empty(), "fresh neighbor had no residents");
        assert_eq!(
            format!("{:?}", sw.stats(TreeId(1)).unwrap()),
            stats_mid,
            "neighbor churn must not touch a resident tenant's stats"
        );
        assert_eq!(format!("{:?}", sw.dedup_stats(TreeId(1))), dedup_mid);

        // Finish the stream: the aggregate equals a solo run's.
        sw.ingest_reliable_one(TreeId(1), refs[refs.len() - 1], &mut sink);
        assert_eq!(sink.flushes, 1);
        let got: Value = sink_to_vec(&sink).iter().map(|p| p.value).sum();
        let want: Value = input.iter().map(|p| p.value).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn evicted_tree_keeps_its_epoch_fence() {
        let cfg = SwitchConfig::scaled(64 << 10, None);
        let q = QuotaRequest::even_split(&cfg, 4);
        let mut sw = SwitchAggSwitch::new(cfg);
        sw.admit_tree(tc(1, 1), q, 1).unwrap();
        sw.begin_epoch(TreeId(1), 2);
        sw.evict_tree(TreeId(1)).unwrap();
        assert_eq!(sw.tree_epoch(TreeId(1)), 2, "fence survives eviction");
        // Re-admission continues the fence: an epoch-0 straggler from
        // the evicted incarnation is still rejected.
        sw.admit_tree(tc(1, 1), q, 1).unwrap();
        let pkt = AggregationPacket {
            tree: TreeId(1),
            op: AggOp::Sum,
            eot: false,
            rel: Some(crate::protocol::RelHeader {
                child: 0,
                epoch: 0,
                seq: 1,
            }),
            pairs: pairs(3, 3, 8),
        };
        let mut sink = IngestSink::new();
        let ack = sw.ingest_reliable_one(TreeId(1), &pkt, &mut sink);
        assert_eq!(ack.epoch, 2);
        assert_eq!(sw.dedup_stats(TreeId(1)).stale_epoch_drops, 1);
    }

    #[test]
    fn try_configure_rejects_zero_capacity_splits() {
        // 64 trees over a tiny FPE: the even split rounds the widest
        // key group down to zero slots — the permissive configure()
        // floors it silently, try_configure must reject it typed.
        let cfg = SwitchConfig::scaled(16 << 10, None);
        let min = cfg.min_fpe_share(1);
        let n = (cfg.fpe_total_mem / min + 1) as u32;
        let trees: Vec<TreeConfig> = (1..=n).map(|i| tc(i, 1)).collect();
        let mut sw = SwitchAggSwitch::new(cfg);
        match sw.try_configure(&trees) {
            Err(AdmissionError::ZeroCapacity { stage: "FPE", share, min: m, .. }) => {
                assert!(share < m);
            }
            other => panic!("expected ZeroCapacity, got {other:?}"),
        }
        assert_eq!(sw.n_trees(), 0, "rejection is side-effect free");
        // A viable split passes and actually configures.
        sw.try_configure(&[tc(1, 1), tc(2, 1)]).unwrap();
        assert_eq!(sw.n_trees(), 2);
    }

    #[test]
    fn weighted_grants_cap_the_flooders_credit() {
        let cfg = SwitchConfig::scaled(64 << 10, None);
        let q = QuotaRequest::even_split(&cfg, 4);
        let mut sw = SwitchAggSwitch::new(cfg);
        sw.set_grant_policy(GrantPolicy::WeightedShare);
        sw.admit_tree(tc(1, 1), q, 16).unwrap(); // well-behaved, heavy
        sw.admit_tree(tc(2, 1), q, 1).unwrap(); // flooder, light
        let window = RelWindow::default().get() as u16;
        let mut sink = IngestSink::new();
        let mk = |tree: u32, seq: u32| AggregationPacket {
            tree: TreeId(tree),
            op: AggOp::Sum,
            eot: false,
            rel: Some(crate::protocol::RelHeader {
                child: 0,
                epoch: 0,
                seq,
            }),
            pairs: vec![KvPair::new(Key::from_id(seq as u64, 16), 1)],
        };
        // Both active: the flooder's grant is capped to its share,
        // the heavy tenant keeps (almost) the whole window.
        let ack_hi = sw.ingest_reliable_one(TreeId(1), &mk(1, 1), &mut sink);
        let ack_lo = sw.ingest_reliable_one(TreeId(2), &mk(2, 1), &mut sink);
        let grants = WeightedGrants::new(window);
        assert_eq!(ack_lo.credit, grants.share(1, 17));
        assert!(ack_hi.credit >= grants.share(16, 17));
        assert!(ack_lo.credit < ack_hi.credit);
        // The heavy tenant goes idle: the flooder gets the full window
        // again — isolation only throttles when someone needs it.
        sw.set_tenant_idle(TreeId(1), true);
        let ack_solo = sw.ingest_reliable_one(TreeId(2), &mk(2, 2), &mut sink);
        assert!(ack_solo.credit > ack_lo.credit);
    }

    #[test]
    fn saturated_combines_are_counted_and_engine_invariant() {
        // Three MAX-valued pairs on one key: the first combine clamps,
        // and so does every one after it.
        let input: Vec<KvPair> =
            (0..3).map(|_| KvPair::new(Key::from_id(7, 16), Value::MAX)).collect();
        let mut serial = configured_switch(64 << 10, None, 1);
        let out = serial.ingest_stream(TreeId(1), AggOp::Sum, &input);
        assert_eq!(out.iter().map(|p| p.value).max(), Some(Value::MAX));
        let s = serial.stats(TreeId(1)).unwrap();
        assert_eq!(s.saturated_combines, 2, "every MAX+MAX combine clamps");

        // Benign traffic never saturates…
        let mut benign = configured_switch(64 << 10, Some(1 << 20), 1);
        benign.ingest_stream(TreeId(1), AggOp::Sum, &pairs(20_000, 500, 42));
        assert_eq!(benign.stats(TreeId(1)).unwrap().saturated_combines, 0);

        // …and the sharded engine reports the identical count.
        let mut sharded = configured_switch(64 << 10, None, 1);
        sharded.set_parallelism(crate::switch::parallel::Parallelism::Sharded(4));
        sharded.ingest_stream(TreeId(1), AggOp::Sum, &input);
        assert_eq!(sharded.stats(TreeId(1)).unwrap().saturated_combines, 2);
    }

    #[test]
    fn audit_passes_clean_and_catches_injected_sram_flip() {
        let mut sw = configured_switch(64 << 10, Some(1 << 20), 1);
        // No engine yet for tree 9: auditing it is a typed error.
        assert_eq!(
            sw.audit_tree(TreeId(9)),
            Err(IntegrityError::Unconfigured { tree: TreeId(9) })
        );
        // Leave residents in memory (no EoT → no flush): a clean run
        // audits clean.
        let pkts =
            AggregationPacket::pack_stream(TreeId(1), AggOp::Sum, &pairs(5_000, 800, 13), false);
        let mut sink = IngestSink::new();
        for pkt in &pkts {
            sw.ingest_into(pkt, &mut sink);
        }
        sw.audit_tree(TreeId(1)).expect("clean memory must audit clean");
        // One flipped bit anywhere in resident state is detected.
        assert!(sw.inject_sram_flip(TreeId(1), 0xDEAD_BEEF_CAFE));
        match sw.audit_tree(TreeId(1)) {
            Err(IntegrityError::AuditMismatch { tree, expected, computed, .. }) => {
                assert_eq!(tree, TreeId(1));
                assert_ne!(expected, computed);
            }
            other => panic!("expected AuditMismatch, got {other:?}"),
        }
    }

    #[test]
    fn corrupt_drop_accounting_survives_crash() {
        let mut sw = configured_switch(64 << 10, None, 2);
        assert_eq!(sw.corrupt_drops(TreeId(1)), 0);
        sw.note_corrupt_drop(TreeId(1));
        sw.note_corrupt_drop(TreeId(1));
        assert_eq!(sw.corrupt_drops(TreeId(1)), 2);
        assert_eq!(sw.dedup_stats(TreeId(1)).corrupt_drops, 2);
        // Simulator accounting, not soft state: a power cycle keeps it.
        sw.crash();
        assert_eq!(sw.corrupt_drops(TreeId(1)), 2);
        assert_eq!(sw.corrupt_drops(TreeId(2)), 0);
    }
}
