//! The assembled SwitchAgg device (Fig. 4): header extraction →
//! payload analyzer → crossbar → FPEs → scheduler → BPE, plus the
//! forwarding and configuration modules.
//!
//! Timing: aggregation pairs arrive paced by the 10 Gbps input link
//! (16 B datapath beats at 200 MHz ⇒ 0.16 cycles/byte), flow through
//! the crossbar (2 cycles), are accepted by their group's FPE every
//! `fpe_interval` cycles and, on eviction, ride the scheduler into the
//! BPE.  All FIFO occupancy / full events are recorded per Table 2;
//! per-stage latencies per Table 3.

use crate::protocol::{
    AggOp, AggregationPacket, Key, KvPair, TreeConfig, TreeId, Value, AGG_FIXED_LEN,
    HEADER_OVERHEAD, MAX_AGG_PAYLOAD,
};
use crate::sim::clock::{Cycles, CLOCK_HZ};
use crate::switch::bpe::{Bpe, BpeOutcome};
use crate::switch::config::{ConfigModule, SwitchConfig};
use crate::switch::crossbar::Crossbar;
use crate::switch::fpe::{Fpe, FpeOutcome};
use crate::switch::forwarding::Forwarding;
use crate::switch::hash_table::HashTable;
use crate::switch::header_extract::HeaderExtract;
use crate::switch::payload_analyzer::{GroupMap, PayloadAnalyzer};
use crate::switch::scheduler::{SchedPolicy, Scheduler};
use std::collections::BTreeMap;

/// Input pacing: cycles per byte on a 10 Gbps port at 200 MHz
/// (1.25 GB/s ÷ 200 Mcycle/s = 6.25 B/cycle = 4/25 cycle/B).
const PACE_NUM: u64 = 4;
const PACE_DEN: u64 = 25;

/// Per-tree aggregate statistics (port counters, §6.2 methodology).
#[derive(Clone, Debug, Default)]
pub struct SwitchStats {
    pub pairs_in: u64,
    pub bytes_in: u64,
    pub packets_in: u64,
    /// Pairs forwarded downstream mid-stream (evictions/overflow).
    pub pairs_out_stream: u64,
    /// Pairs flushed at end of tree.
    pub pairs_out_flush: u64,
    pub bytes_out: u64,
    pub fpe_aggregated: u64,
    pub fpe_inserted: u64,
    pub fpe_evicted: u64,
    pub bpe_aggregated: u64,
    pub bpe_inserted: u64,
    pub bpe_overflowed: u64,
    pub fifo_writes: u64,
    pub fifo_full_events: u64,
    pub flush_cycles: Cycles,
    /// Cycle at which the last pair finished processing.
    pub makespan_cycles: Cycles,
}

impl SwitchStats {
    /// Paper's reduction ratio R = 1 − out/in over wire bytes.
    pub fn reduction_ratio(&self) -> f64 {
        if self.bytes_in == 0 {
            0.0
        } else {
            1.0 - self.bytes_out as f64 / self.bytes_in as f64
        }
    }

    /// Table 2 "Full-time ratio".
    pub fn fifo_full_ratio(&self) -> f64 {
        if self.fifo_writes == 0 {
            0.0
        } else {
            self.fifo_full_events as f64 / self.fifo_writes as f64
        }
    }

    /// Effective processing throughput in bytes/sec over the makespan.
    pub fn throughput_bytes_per_sec(&self) -> f64 {
        if self.makespan_cycles == 0 {
            0.0
        } else {
            self.bytes_in as f64 * CLOCK_HZ as f64 / self.makespan_cycles as f64
        }
    }
}

/// Everything the switch emits while ingesting one packet.
#[derive(Clone, Debug, Default)]
pub struct IngestOutput {
    /// Pairs leaving downstream immediately (evictions, overflow).
    pub forwarded: Vec<KvPair>,
    /// Set when this packet completed the tree (all children EoT):
    /// the flushed residents.
    pub flushed: Option<Vec<KvPair>>,
}

/// One aggregation tree's slice of the data plane.
struct TreeEngine {
    op: AggOp,
    children: u16,
    eot_seen: u16,
    analyzer: PayloadAnalyzer,
    crossbar: Crossbar,
    scheduler: Scheduler,
    fpes: Vec<Fpe>,
    bpe: Option<Bpe>,
    /// Byte-pacing accumulator for input arrivals.
    bytes_arrived: u64,
    /// Scratch queue-depth buffer for scheduler grants (avoids a per-
    /// eviction allocation on the hot path).
    depths_scratch: Vec<usize>,
    stats: SwitchStats,
}

impl TreeEngine {
    fn new(cfg: &SwitchConfig, op: AggOp, children: u16, fpe_share: u64, bpe_share: Option<u64>) -> Self {
        let fpe_mem_each = fpe_share / cfg.n_groups as u64;
        let map = GroupMap::new(cfg.n_groups, cfg.key_base);
        let fpes = (0..cfg.n_groups)
            .map(|g| {
                let table = HashTable::with_memory(
                    fpe_mem_each,
                    cfg.group_width(g),
                    cfg.fpe_slots_per_bucket,
                );
                Fpe::new(
                    g,
                    table,
                    cfg.fpe_interval,
                    cfg.delays,
                    cfg.eviction,
                    cfg.fifo_cap,
                )
            })
            .collect();
        let bpe = bpe_share.map(|m| Bpe::for_tree(cfg, m));
        Self {
            op,
            children,
            eot_seen: 0,
            analyzer: PayloadAnalyzer::new(map),
            crossbar: Crossbar::new(cfg.n_groups, cfg.delays.crossbar),
            scheduler: Scheduler::new(cfg.n_groups, SchedPolicy::RoundRobin),
            depths_scratch: vec![0; cfg.n_groups],
            fpes,
            bpe,
            bytes_arrived: 0,
            stats: SwitchStats::default(),
        }
    }

    /// Current arrival cycle implied by bytes received at line rate.
    /// Each child feeds its own 10 Gbps port through its own payload
    /// analyzer (§5 instantiates one PA per port), so the aggregate
    /// ingress rate scales with the child count: pairs from k children
    /// land on the shared FPEs k× as fast as a single stream would.
    fn arrival_cycle(&self) -> Cycles {
        let ports = (self.children as u64).max(1);
        self.bytes_arrived * PACE_NUM / (PACE_DEN * ports)
    }

    fn ingest(&mut self, pkt: &AggregationPacket, header_delay: Cycles) -> IngestOutput {
        let mut out = IngestOutput::default();
        self.stats.packets_in += 1;
        self.stats.bytes_in += pkt.wire_len() as u64;
        self.bytes_arrived += (HEADER_OVERHEAD + AGG_FIXED_LEN) as u64;

        for p in &pkt.pairs {
            self.bytes_arrived += p.encoded_len() as u64;
            self.stats.pairs_in += 1;
            let arrive = self.arrival_cycle() + header_delay;
            let g = self.analyzer.classify(p);
            let deliver = self.crossbar.route(arrive, g);
            match self.fpes[g].offer(deliver, p.key, p.value, self.op) {
                FpeOutcome::Kept => {}
                FpeOutcome::Forwarded {
                    key,
                    value,
                    hash,
                    ready,
                } => {
                    self.forward_evicted(g, key, value, hash, ready, &mut out);
                }
            }
        }

        if pkt.eot {
            self.eot_seen += 1;
            if self.eot_seen >= self.children {
                let flushed = self.flush();
                out.flushed = Some(flushed);
            }
        }
        self.roll_stats();
        out
    }

    /// Route an FPE-evicted pair: to the BPE if the hierarchy is on,
    /// straight downstream otherwise (fig9 "S-" single-level rows).
    fn forward_evicted(
        &mut self,
        group: usize,
        key: Key,
        value: Value,
        hash: u32,
        ready: Cycles,
        out: &mut IngestOutput,
    ) {
        match &mut self.bpe {
            Some(bpe) => {
                // The scheduler grants this FPE's forward queue; depths
                // are instantaneous (event-driven model).
                self.depths_scratch.fill(0);
                self.depths_scratch[group] = 1;
                let granted = self.scheduler.pick(&self.depths_scratch).expect("nonempty queue");
                debug_assert_eq!(granted, group);
                match bpe.offer_hashed(ready, group, key, value, hash, self.op) {
                    BpeOutcome::Kept => {}
                    BpeOutcome::Overflow { key, value, .. } => {
                        self.emit_pair(KvPair::new(key, value), out);
                    }
                }
            }
            None => self.emit_pair(KvPair::new(key, value), out),
        }
    }

    fn emit_pair(&mut self, p: KvPair, out: &mut IngestOutput) {
        self.stats.pairs_out_stream += 1;
        self.stats.bytes_out += p.encoded_len() as u64;
        out.forwarded.push(p);
    }

    /// Flush every engine (EoT from all children, §4.2.2): residents
    /// stream downstream; Table 3's BPE-Flush dominates the cost.
    fn flush(&mut self) -> Vec<KvPair> {
        let mut pairs: Vec<KvPair> = Vec::new();
        let mut flush_cycles: Cycles = 0;
        for f in &mut self.fpes {
            let (resident, cyc) = f.flush();
            flush_cycles += cyc;
            pairs.extend(resident.into_iter().map(|(k, v)| KvPair::new(k, v)));
        }
        if let Some(bpe) = &mut self.bpe {
            let (resident, cyc) = bpe.flush();
            flush_cycles += cyc;
            pairs.extend(resident.into_iter().map(|(k, v)| KvPair::new(k, v)));
        }
        self.stats.flush_cycles += flush_cycles;
        self.stats.pairs_out_flush += pairs.len() as u64;
        self.stats.bytes_out += pairs.iter().map(|p| p.encoded_len() as u64).sum::<u64>();
        self.eot_seen = 0;
        pairs
    }

    /// Fold engine counters into the per-tree stats snapshot.
    fn roll_stats(&mut self) {
        let fpe_aggregated = self.fpes.iter().map(|f| f.aggregated).sum();
        let fpe_inserted = self.fpes.iter().map(|f| f.inserted).sum();
        let fpe_evicted = self.fpes.iter().map(|f| f.evicted).sum();
        let mut fifo_writes: u64 = self.fpes.iter().map(|f| f.fifo_writes).sum();
        let mut fifo_full: u64 = self.fpes.iter().map(|f| f.fifo_full_events).sum();
        if let Some(b) = &self.bpe {
            self.stats.bpe_aggregated = b.aggregated;
            self.stats.bpe_inserted = b.inserted;
            self.stats.bpe_overflowed = b.overflowed;
            fifo_writes += b.fifo_writes;
            fifo_full += b.fifo_full_events;
        }
        self.stats.fpe_aggregated = fpe_aggregated;
        self.stats.fpe_inserted = fpe_inserted;
        self.stats.fpe_evicted = fpe_evicted;
        self.stats.fifo_writes = fifo_writes;
        self.stats.fifo_full_events = fifo_full;
        self.stats.makespan_cycles = self.arrival_cycle();
    }

    /// Account trailing per-packet header overhead on the output side:
    /// streamed-out pairs are packed into MTU-sized packets downstream.
    fn finalize_output_bytes(&mut self) {
        let payload = self.stats.bytes_out;
        let pkts = payload.div_ceil(MAX_AGG_PAYLOAD as u64).max(
            (self.stats.pairs_out_stream + self.stats.pairs_out_flush > 0) as u64,
        );
        self.stats.bytes_out = payload + pkts * (HEADER_OVERHEAD + AGG_FIXED_LEN) as u64;
    }
}

/// The full switch.
pub struct SwitchAggSwitch {
    cfg: SwitchConfig,
    pub header_extract: HeaderExtract,
    pub forwarding: Forwarding,
    config_module: ConfigModule,
    trees: BTreeMap<TreeId, TreeEngine>,
}

impl SwitchAggSwitch {
    pub fn new(cfg: SwitchConfig) -> Self {
        Self {
            cfg,
            header_extract: HeaderExtract::new(),
            forwarding: Forwarding::new(),
            config_module: ConfigModule::new(),
            trees: BTreeMap::new(),
        }
    }

    pub fn config(&self) -> &SwitchConfig {
        &self.cfg
    }

    /// Apply a Configure packet (§4.2.2).  Memory is re-partitioned
    /// among all configured trees per the active [`MemoryPolicy`]
    /// (even by default, demand-weighted per §7 if hints were
    /// announced); engines are (re)built, so configuration must
    /// precede data for those trees.
    pub fn configure(&mut self, trees: &[TreeConfig]) {
        self.config_module.apply(trees);
        // Rebuild engines for all trees with the new share.
        let ids: Vec<TreeId> = self.config_module.tree_ids().collect();
        for id in ids {
            let tc = self.config_module.get(id).unwrap().clone();
            let fpe_share = self.config_module.memory_share_for(id, self.cfg.fpe_total_mem);
            let bpe_share = self
                .cfg
                .bpe_mem
                .map(|m| self.config_module.memory_share_for(id, m));
            self.forwarding.install_tree_parent(id, tc.parent_port);
            self.trees.insert(
                id,
                TreeEngine::new(&self.cfg, tc.op, tc.children, fpe_share, bpe_share),
            );
        }
    }

    /// Announce a tree's relative memory demand (application hint, §7
    /// "Memory Utilization"); takes effect at the next `configure`.
    pub fn set_memory_policy(&mut self, policy: crate::switch::config::MemoryPolicy) {
        self.config_module.policy = policy;
    }

    /// Set a tree's demand weight (used by the Weighted policy).
    pub fn set_tree_weight(&mut self, tree: TreeId, weight: u64) {
        self.config_module.set_weight(tree, weight);
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Ingest one aggregation packet for its tree.
    pub fn ingest(&mut self, pkt: &AggregationPacket) -> IngestOutput {
        let engine = self
            .trees
            .get_mut(&pkt.tree)
            .unwrap_or_else(|| panic!("tree {} not configured", pkt.tree));
        engine.ingest(pkt, self.cfg.delays.header_analyzer)
    }

    /// Convenience: run a whole pair stream (pre-packed into MTU
    /// packets) through one tree; the last packet carries EoT counted
    /// once per `children`, so pass the merged stream of all children
    /// with `eot_per_child` packets at the end — or use
    /// [`Self::ingest_child_streams`].
    pub fn ingest_stream(&mut self, tree: TreeId, op: AggOp, pairs: &[KvPair]) -> Vec<KvPair> {
        let mut out = Vec::new();
        let children = self
            .config_module
            .get(tree)
            .map(|t| t.children)
            .unwrap_or(1);
        // Merged stream: emit children EoTs by splitting at the end
        // (Theorem 2.1: merging flows preserves the reduction ratio).
        let pkts = AggregationPacket::pack_stream(tree, op, pairs, false);
        for pkt in &pkts {
            out.extend(self.ingest(pkt).forwarded);
        }
        for _ in 0..children {
            let eot = AggregationPacket {
                tree,
                op,
                eot: true,
                pairs: vec![],
            };
            let r = self.ingest(&eot);
            out.extend(r.forwarded);
            if let Some(flushed) = r.flushed {
                out.extend(flushed);
            }
        }
        self.finalize(tree);
        out
    }

    /// Ingest per-child streams interleaved round-robin packet-wise —
    /// the many-to-one pattern of Fig. 1.
    pub fn ingest_child_streams(
        &mut self,
        tree: TreeId,
        op: AggOp,
        streams: &[Vec<KvPair>],
    ) -> Vec<KvPair> {
        let mut out = Vec::new();
        let packed: Vec<Vec<AggregationPacket>> = streams
            .iter()
            .map(|s| AggregationPacket::pack_stream(tree, op, s, true))
            .collect();
        let max_len = packed.iter().map(|p| p.len()).max().unwrap_or(0);
        for i in 0..max_len {
            for child in &packed {
                if let Some(pkt) = child.get(i) {
                    let r = self.ingest(pkt);
                    out.extend(r.forwarded);
                    if let Some(flushed) = r.flushed {
                        out.extend(flushed);
                    }
                }
            }
        }
        self.finalize(tree);
        out
    }

    /// Close output byte accounting (packetization of the out stream).
    pub fn finalize(&mut self, tree: TreeId) {
        if let Some(e) = self.trees.get_mut(&tree) {
            e.finalize_output_bytes();
        }
    }

    pub fn stats(&self, tree: TreeId) -> Option<&SwitchStats> {
        self.trees.get(&tree).map(|e| &e.stats)
    }

    /// Average measured FPE pair latency in cycles (Table 3 check).
    pub fn avg_fpe_latency(&self, tree: TreeId) -> f64 {
        let e = &self.trees[&tree];
        let pairs: u64 = e.fpes.iter().map(|f| f.aggregated + f.inserted + f.evicted).sum();
        let cyc: u64 = e.fpes.iter().map(|f| f.latency_cycles).sum();
        if pairs == 0 {
            0.0
        } else {
            cyc as f64 / pairs as f64
        }
    }

    /// Sum of BPE DRAM commands and stall cycles (overlap diagnostics).
    pub fn bpe_dram_stats(&self, tree: TreeId) -> Option<(u64, Cycles)> {
        self.trees[&tree].bpe.as_ref().map(|b| b.dram_stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::packet::TreeConfig;
    use crate::util::rng::Pcg32;

    fn configured_switch(fpe_mem: u64, bpe_mem: Option<u64>, children: u16) -> SwitchAggSwitch {
        let cfg = SwitchConfig::scaled(fpe_mem, bpe_mem);
        let mut sw = SwitchAggSwitch::new(cfg);
        sw.configure(&[TreeConfig {
            tree: TreeId(1),
            children,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        sw
    }

    fn pairs(n: usize, distinct: u64, seed: u64) -> Vec<KvPair> {
        let mut rng = Pcg32::new(seed);
        (0..n)
            .map(|_| {
                let id = rng.gen_range_u64(distinct);
                KvPair::new(Key::from_id(id, 16 + (id % 49) as usize), 1)
            })
            .collect()
    }

    #[test]
    fn sum_is_conserved_through_the_switch() {
        let mut sw = configured_switch(64 << 10, Some(1 << 20), 1);
        let input = pairs(20_000, 500, 42);
        let want: Value = input.iter().map(|p| p.value).sum();
        let out = sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
        let got: Value = out.iter().map(|p| p.value).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn keys_fully_aggregated_when_memory_sufficient() {
        let mut sw = configured_switch(4 << 20, Some(8 << 20), 1);
        let input = pairs(10_000, 100, 7);
        let out = sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
        // Every distinct key appears exactly once in the output.
        let mut seen = std::collections::HashMap::new();
        for p in &out {
            *seen.entry(p.key).or_insert(0u32) += 1;
        }
        assert!(seen.values().all(|&c| c == 1), "duplicate keys in output");
        assert_eq!(seen.len() as u64, 100);
        let s = sw.stats(TreeId(1)).unwrap();
        assert!(s.reduction_ratio() > 0.9, "r={}", s.reduction_ratio());
    }

    #[test]
    fn small_memory_reduces_reduction_ratio() {
        let big = {
            let mut sw = configured_switch(4 << 20, None, 1);
            sw.ingest_stream(TreeId(1), AggOp::Sum, &pairs(50_000, 20_000, 3));
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        let small = {
            let mut sw = configured_switch(16 << 10, None, 1);
            sw.ingest_stream(TreeId(1), AggOp::Sum, &pairs(50_000, 20_000, 3));
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        assert!(big > small, "big={big} small={small}");
    }

    #[test]
    fn multilevel_beats_single_level() {
        let input = pairs(60_000, 30_000, 9);
        let single = {
            let mut sw = configured_switch(32 << 10, None, 1);
            sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        let multi = {
            let mut sw = configured_switch(32 << 10, Some(4 << 20), 1);
            sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        assert!(multi > single + 0.2, "multi={multi} single={single}");
    }

    #[test]
    fn eot_from_all_children_triggers_flush() {
        let mut sw = configured_switch(1 << 20, Some(1 << 20), 3);
        let streams: Vec<Vec<KvPair>> =
            (0..3).map(|i| pairs(1000, 50, i as u64)).collect();
        let out = sw.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
        let s = sw.stats(TreeId(1)).unwrap();
        assert!(s.pairs_out_flush > 0);
        assert_eq!(s.packets_in > 0, true);
        let want: Value = streams.iter().flatten().map(|p| p.value).sum();
        let got: Value = out.iter().map(|p| p.value).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn fifo_full_ratio_is_small_at_line_rate() {
        let mut sw = configured_switch(256 << 10, Some(4 << 20), 1);
        let input = pairs(100_000, 50_000, 11);
        sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
        let s = sw.stats(TreeId(1)).unwrap();
        assert!(s.fifo_writes >= 100_000);
        assert!(
            s.fifo_full_ratio() < 0.01,
            "full ratio {} too high",
            s.fifo_full_ratio()
        );
    }

    #[test]
    fn two_trees_split_memory() {
        let cfg = SwitchConfig::scaled(64 << 10, None);
        let mut sw = SwitchAggSwitch::new(cfg);
        let mk = |id| TreeConfig {
            tree: TreeId(id),
            children: 1,
            parent_port: 0,
            op: AggOp::Sum,
        };
        sw.configure(&[mk(1), mk(2)]);
        assert_eq!(sw.n_trees(), 2);
        let input = pairs(30_000, 10_000, 5);
        let r2trees = {
            sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        let mut solo = SwitchAggSwitch::new(SwitchConfig::scaled(64 << 10, None));
        solo.configure(&[mk(1)]);
        solo.ingest_stream(TreeId(1), AggOp::Sum, &input);
        let r1tree = solo.stats(TreeId(1)).unwrap().reduction_ratio();
        assert!(
            r1tree > r2trees,
            "memory halving should hurt: solo={r1tree} shared={r2trees}"
        );
    }

    #[test]
    #[should_panic(expected = "not configured")]
    fn unconfigured_tree_panics() {
        let mut sw = SwitchAggSwitch::new(SwitchConfig::default());
        let pkt = AggregationPacket {
            tree: TreeId(9),
            op: AggOp::Sum,
            eot: false,
            pairs: vec![],
        };
        sw.ingest(&pkt);
    }
}
