//! The assembled SwitchAgg device (Fig. 4): header extraction →
//! payload analyzer → crossbar → FPEs → scheduler → BPE, plus the
//! forwarding and configuration modules.
//!
//! Timing: aggregation pairs arrive paced by the 10 Gbps input link
//! (16 B datapath beats at 200 MHz ⇒ 0.16 cycles/byte), flow through
//! the crossbar (2 cycles), are accepted by their group's FPE every
//! `fpe_interval` cycles and, on eviction, ride the scheduler into the
//! BPE.  All FIFO occupancy / full events are recorded per Table 2;
//! per-stage latencies per Table 3.
//!
//! # Allocation discipline
//!
//! The per-pair loop is the simulator's hot path, so the ingest API is
//! sink-based: callers own an [`IngestSink`] whose buffers are reused
//! across packets, and the stream entry points
//! ([`SwitchAggSwitch::ingest_stream`] /
//! [`SwitchAggSwitch::ingest_child_streams`]) walk MTU-sized *chunks*
//! of the caller's pair slice instead of materializing packet objects
//! — in steady state the data plane performs no per-packet heap
//! allocation (see `EXPERIMENTS.md` §Perf).

use crate::protocol::packet::MtuChunks;
use crate::protocol::vector::{max_vec_payload, vec_fixed_len, VectorChunks};
use crate::protocol::{
    AggAckPacket, AggOp, AggregationPacket, Key, KvPair, RelWindow, TreeConfig, TreeId, Value,
    VectorBatch, AGG_FIXED_LEN, HEADER_OVERHEAD,
};
use crate::sim::clock::{Cycles, CLOCK_HZ};
use crate::switch::bpe::{Bpe, BpeOutcome};
use crate::switch::config::{ConfigModule, EvictionPolicy, SwitchConfig};
use crate::switch::crossbar::Crossbar;
use crate::switch::fpe::{Fpe, FpeOutcome};
use crate::switch::forwarding::Forwarding;
use crate::switch::hash_table::{HashTable, VectorEvictSink};
use crate::switch::header_extract::HeaderExtract;
use crate::switch::parallel::{merge_by_seq, run_workers, JobPair, Parallelism, WorkerGroup};
use crate::switch::payload_analyzer::{GroupMap, PayloadAnalyzer};
use crate::switch::reliability::{backpressure_credit, Admit, CreditPolicy, DedupStats, DedupWindow};
use crate::switch::scheduler::{SchedPolicy, Scheduler};
use std::collections::BTreeMap;

/// Input pacing: cycles per byte on a 10 Gbps port at 200 MHz
/// (1.25 GB/s ÷ 200 Mcycle/s = 6.25 B/cycle = 4/25 cycle/B).
const PACE_NUM: u64 = 4;
const PACE_DEN: u64 = 25;

/// Per-tree aggregate statistics (port counters, §6.2 methodology).
#[derive(Clone, Debug, Default)]
pub struct SwitchStats {
    pub pairs_in: u64,
    pub bytes_in: u64,
    pub packets_in: u64,
    /// Pairs forwarded downstream mid-stream (evictions/overflow).
    pub pairs_out_stream: u64,
    /// Pairs flushed at end of tree.
    pub pairs_out_flush: u64,
    pub bytes_out: u64,
    pub fpe_aggregated: u64,
    pub fpe_inserted: u64,
    pub fpe_evicted: u64,
    pub bpe_aggregated: u64,
    pub bpe_inserted: u64,
    pub bpe_overflowed: u64,
    pub fifo_writes: u64,
    pub fifo_full_events: u64,
    /// Peak PE-input FIFO occupancy across all FPEs and the BPE
    /// (capped at `fifo_cap`) — the queue-depth signal the
    /// congestion-aware credit advertisement and the incast experiment
    /// read (`sim::Fifo::max_occupancy`'s counterpart on the analytic
    /// FIFO model).
    pub fifo_max_occupancy: u64,
    /// Times the sharded engine silently took the serial loop because
    /// an end-of-tree flush would have split the chunk stream —
    /// benchmarks must check this before attributing numbers to the
    /// sharded path.
    pub fallback_serial: u64,
    pub flush_cycles: Cycles,
    /// Cycle at which the last pair finished processing.
    pub makespan_cycles: Cycles,
}

impl SwitchStats {
    /// Paper's reduction ratio R = 1 − out/in over wire bytes.
    pub fn reduction_ratio(&self) -> f64 {
        if self.bytes_in == 0 {
            0.0
        } else {
            1.0 - self.bytes_out as f64 / self.bytes_in as f64
        }
    }

    /// Table 2 "Full-time ratio".
    pub fn fifo_full_ratio(&self) -> f64 {
        if self.fifo_writes == 0 {
            0.0
        } else {
            self.fifo_full_events as f64 / self.fifo_writes as f64
        }
    }

    /// Effective processing throughput in bytes/sec over the makespan.
    pub fn throughput_bytes_per_sec(&self) -> f64 {
        if self.makespan_cycles == 0 {
            0.0
        } else {
            self.bytes_in as f64 * CLOCK_HZ as f64 / self.makespan_cycles as f64
        }
    }
}

/// Everything the switch emits while ingesting one packet (owning
/// variant, built by the compatibility wrapper [`SwitchAggSwitch::ingest`]).
#[derive(Clone, Debug, Default)]
pub struct IngestOutput {
    /// Pairs leaving downstream immediately (evictions, overflow).
    pub forwarded: Vec<KvPair>,
    /// Set when this packet completed the tree (all children EoT):
    /// the flushed residents.
    pub flushed: Option<Vec<KvPair>>,
}

/// Caller-owned, reusable output sink for the ingest path: the switch
/// *appends*, the caller clears — so a steady-state ingest loop does no
/// per-packet heap allocation once the buffers have warmed up.
#[derive(Clone, Debug, Default)]
pub struct IngestSink {
    /// Pairs leaving downstream immediately (evictions, overflow).
    pub forwarded: Vec<KvPair>,
    /// Residents streamed out by end-of-tree flushes.
    pub flushed: Vec<KvPair>,
    /// Number of tree completions (flushes) recorded since `clear`.
    pub flushes: u32,
    /// Reused engine-drain scratch.
    scratch: Vec<(Key, Value)>,
}

impl IngestSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty all buffers, keeping their capacity.
    pub fn clear(&mut self) {
        self.forwarded.clear();
        self.flushed.clear();
        self.flushes = 0;
        self.scratch.clear();
    }

    /// Total buffer capacity in elements — used by tests/benches to
    /// assert that steady-state ingest stops allocating.
    pub fn capacity(&self) -> usize {
        self.forwarded.capacity() + self.flushed.capacity() + self.scratch.capacity()
    }
}

/// Caller-owned, reusable output sink for the W-lane vector ingest
/// path — the columnar counterpart of [`IngestSink`]: the switch
/// *appends*, the caller clears, so steady-state vector ingest does no
/// per-packet heap allocation once the buffers have warmed up.
#[derive(Clone, Debug)]
pub struct VectorSink {
    /// W-lane pairs leaving downstream immediately (evictions,
    /// overflow), in emission order.
    pub forwarded: VectorBatch,
    /// Residents streamed out by end-of-tree flushes.
    pub flushed: VectorBatch,
    /// Number of tree completions (flushes) recorded since `clear`.
    pub flushes: u32,
    /// Reused columnar engine-drain scratch.
    scratch_keys: Vec<Key>,
    scratch_vals: Vec<Value>,
}

impl VectorSink {
    pub fn new(lanes: usize) -> Self {
        Self {
            forwarded: VectorBatch::new(lanes),
            flushed: VectorBatch::new(lanes),
            flushes: 0,
            scratch_keys: Vec::new(),
            scratch_vals: Vec::new(),
        }
    }

    pub fn lanes(&self) -> usize {
        self.forwarded.lanes()
    }

    /// Empty all buffers, keeping their capacity.
    pub fn clear(&mut self) {
        self.forwarded.clear();
        self.flushed.clear();
        self.flushes = 0;
        self.scratch_keys.clear();
        self.scratch_vals.clear();
    }

    /// Total buffer capacity in elements (steady-state alloc checks).
    pub fn capacity(&self) -> usize {
        self.forwarded.capacity()
            + self.flushed.capacity()
            + self.scratch_keys.capacity()
            + self.scratch_vals.capacity()
    }
}

/// Concatenate a vector sink's stream + flush output (flushes only
/// happen after the final EoT, so this preserves emission order).
pub fn vector_sink_to_batch(sink: &VectorSink) -> VectorBatch {
    let mut out = VectorBatch::with_capacity(
        sink.forwarded.lanes(),
        sink.forwarded.len() + sink.flushed.len(),
    );
    out.extend_from_batch(&sink.forwarded);
    out.extend_from_batch(&sink.flushed);
    out
}

/// One aggregation tree's slice of the data plane.
struct TreeEngine {
    op: AggOp,
    children: u16,
    eot_seen: u16,
    /// Value lanes per key (W); 1 = the scalar data plane.
    lanes: usize,
    analyzer: PayloadAnalyzer,
    crossbar: Crossbar,
    scheduler: Scheduler,
    fpes: Vec<Fpe>,
    bpe: Option<Bpe>,
    /// Byte-pacing accumulator for input arrivals.
    bytes_arrived: u64,
    /// PE-input FIFO capacity (shared by every FPE and the BPE) — the
    /// denominator of the backpressure-credit headroom.
    fifo_cap: usize,
    /// Reused FPE-eviction scratch for the vector path (one evictee).
    evict_scratch: VectorEvictSink,
    /// Reused BPE-overflow scratch for the vector path (one pair).
    overflow_scratch: VectorEvictSink,
    stats: SwitchStats,
}

impl TreeEngine {
    fn new(
        cfg: &SwitchConfig,
        op: AggOp,
        children: u16,
        fpe_share: u64,
        bpe_share: Option<u64>,
        lanes: usize,
    ) -> Self {
        let fpe_mem_each = fpe_share / cfg.n_groups as u64;
        let map = GroupMap::new(cfg.n_groups, cfg.key_base);
        let fpes = (0..cfg.n_groups)
            .map(|g| {
                let table = HashTable::with_memory_lanes(
                    fpe_mem_each,
                    cfg.group_width(g),
                    cfg.fpe_slots_per_bucket,
                    lanes,
                );
                Fpe::new(
                    g,
                    table,
                    cfg.fpe_interval,
                    cfg.delays,
                    cfg.eviction,
                    cfg.fifo_cap,
                )
            })
            .collect();
        let bpe = bpe_share.map(|m| Bpe::for_tree_lanes(cfg, m, lanes));
        Self {
            op,
            children,
            eot_seen: 0,
            lanes,
            analyzer: PayloadAnalyzer::new(map),
            crossbar: Crossbar::new(cfg.n_groups, cfg.delays.crossbar),
            scheduler: Scheduler::new(cfg.n_groups, SchedPolicy::RoundRobin),
            fpes,
            bpe,
            bytes_arrived: 0,
            fifo_cap: cfg.fifo_cap,
            evict_scratch: VectorEvictSink::new(),
            overflow_scratch: VectorEvictSink::new(),
            stats: SwitchStats::default(),
        }
    }

    /// Current arrival cycle implied by bytes received at line rate.
    /// Each child feeds its own 10 Gbps port through its own payload
    /// analyzer (§5 instantiates one PA per port), so the aggregate
    /// ingress rate scales with the child count: pairs from k children
    /// land on the shared FPEs k× as fast as a single stream would.
    fn arrival_cycle(&self) -> Cycles {
        let ports = (self.children as u64).max(1);
        self.bytes_arrived * PACE_NUM / (PACE_DEN * ports)
    }

    /// Packet-header arrival accounting shared by the serial, sharded,
    /// and vector front ends — with [`Self::account_pair`], the single
    /// source of the input-pacing rule, so the paths cannot drift.
    /// For scalar trees (`lanes == 1`) the fixed length is exactly
    /// [`AGG_FIXED_LEN`]; W-lane trees carry the 2-byte lane count.
    fn account_packet_header(&mut self) {
        let fixed = (HEADER_OVERHEAD + vec_fixed_len(self.lanes)) as u64;
        debug_assert!(self.lanes > 1 || fixed == (HEADER_OVERHEAD + AGG_FIXED_LEN) as u64);
        self.stats.packets_in += 1;
        self.stats.bytes_in += fixed;
        self.bytes_arrived += fixed;
    }

    /// Per-pair arrival accounting (bytes, pacing, payload analyzer);
    /// returns the pair's `(group, arrival cycle)`.
    fn account_pair(&mut self, p: &KvPair, header_delay: Cycles) -> (usize, Cycles) {
        let el = p.encoded_len() as u64;
        self.stats.bytes_in += el;
        self.bytes_arrived += el;
        self.stats.pairs_in += 1;
        let arrive = self.arrival_cycle() + header_delay;
        let g = self.analyzer.classify(p);
        (g, arrive)
    }

    /// Ingest one packet's worth of pairs.  This is the core ingest
    /// path: the packet need not be materialized — stream entry points
    /// pass MTU-sized chunks of the caller's slice directly.
    fn ingest_pairs(
        &mut self,
        pairs: &[KvPair],
        eot: bool,
        header_delay: Cycles,
        out: &mut IngestSink,
    ) {
        assert_eq!(
            self.lanes, 1,
            "scalar ingest on a tree configured for {}-lane vector payloads",
            self.lanes
        );
        self.account_packet_header();

        for p in pairs {
            let (g, arrive) = self.account_pair(p, header_delay);
            let deliver = self.crossbar.route(arrive, g);
            match self.fpes[g].offer(deliver, p.key, p.value, self.op) {
                FpeOutcome::Kept => {}
                FpeOutcome::Forwarded {
                    key,
                    value,
                    hash,
                    ready,
                } => {
                    self.forward_evicted(g, key, value, hash, ready, out);
                }
            }
        }

        if eot {
            self.eot_seen += 1;
            if self.eot_seen >= self.children {
                self.flush_into(out);
            }
        }
        self.roll_stats();
    }

    /// Route an FPE-evicted pair: to the BPE if the hierarchy is on,
    /// straight downstream otherwise (fig9 "S-" single-level rows).
    fn forward_evicted(
        &mut self,
        group: usize,
        key: Key,
        value: Value,
        hash: u32,
        ready: Cycles,
        out: &mut IngestSink,
    ) {
        match &mut self.bpe {
            Some(bpe) => {
                // The scheduler grants this FPE's forward queue; the
                // event-driven model presents evictions one at a time,
                // so the queue-depth vector would be a singleton.
                let granted = self.scheduler.grant_single(group);
                debug_assert_eq!(granted, group);
                match bpe.offer_hashed(ready, group, key, value, hash, self.op) {
                    BpeOutcome::Kept => {}
                    BpeOutcome::Overflow { key, value, .. } => {
                        self.emit_pair(KvPair::new(key, value), out);
                    }
                }
            }
            None => self.emit_pair(KvPair::new(key, value), out),
        }
    }

    fn emit_pair(&mut self, p: KvPair, out: &mut IngestSink) {
        self.stats.pairs_out_stream += 1;
        self.stats.bytes_out += p.encoded_len() as u64;
        out.forwarded.push(p);
    }

    /// Flush every engine (EoT from all children, §4.2.2): residents
    /// stream downstream; Table 3's BPE-Flush dominates the cost.
    fn flush_into(&mut self, out: &mut IngestSink) {
        out.flushes += 1;
        let start = out.flushed.len();
        let mut flush_cycles: Cycles = 0;
        for f in &mut self.fpes {
            out.scratch.clear();
            flush_cycles += f.flush_into(&mut out.scratch);
            out.flushed
                .extend(out.scratch.iter().map(|&(k, v)| KvPair::new(k, v)));
        }
        if let Some(bpe) = &mut self.bpe {
            out.scratch.clear();
            flush_cycles += bpe.flush_into(&mut out.scratch);
            out.flushed
                .extend(out.scratch.iter().map(|&(k, v)| KvPair::new(k, v)));
        }
        self.stats.flush_cycles += flush_cycles;
        let flushed_now = &out.flushed[start..];
        self.stats.pairs_out_flush += flushed_now.len() as u64;
        self.stats.bytes_out += flushed_now.iter().map(|p| p.encoded_len() as u64).sum::<u64>();
        self.eot_seen = 0;
    }

    /// Fold engine counters into the per-tree stats snapshot.
    fn roll_stats(&mut self) {
        let fpe_aggregated = self.fpes.iter().map(|f| f.aggregated).sum();
        let fpe_inserted = self.fpes.iter().map(|f| f.inserted).sum();
        let fpe_evicted = self.fpes.iter().map(|f| f.evicted).sum();
        let mut fifo_writes: u64 = self.fpes.iter().map(|f| f.fifo_writes).sum();
        let mut fifo_full: u64 = self.fpes.iter().map(|f| f.fifo_full_events).sum();
        if let Some(b) = &self.bpe {
            self.stats.bpe_aggregated = b.aggregated;
            self.stats.bpe_inserted = b.inserted;
            self.stats.bpe_overflowed = b.overflowed;
            fifo_writes += b.fifo_writes;
            fifo_full += b.fifo_full_events;
        }
        self.stats.fpe_aggregated = fpe_aggregated;
        self.stats.fpe_inserted = fpe_inserted;
        self.stats.fpe_evicted = fpe_evicted;
        self.stats.fifo_writes = fifo_writes;
        self.stats.fifo_full_events = fifo_full;
        let mut fifo_peak: u64 = self.fpes.iter().map(|f| f.fifo_peak).max().unwrap_or(0);
        if let Some(b) = &self.bpe {
            fifo_peak = fifo_peak.max(b.fifo_peak);
        }
        self.stats.fifo_max_occupancy = fifo_peak;
        self.stats.makespan_cycles = self.arrival_cycle();
    }

    /// Instantaneous PE-input queue state as seen by the next arrival:
    /// `(deepest FIFO, capacity)` — the backpressure signal behind
    /// [`CreditPolicy::Backpressure`]'s credit advertisement.
    fn input_queue(&self) -> (usize, usize) {
        let at = self.arrival_cycle();
        let mut depth = self
            .fpes
            .iter()
            .map(|f| f.fifo_depth_at(at))
            .max()
            .unwrap_or(0);
        if let Some(b) = &self.bpe {
            depth = depth.max(b.fifo_depth_at(at));
        }
        (depth, self.fifo_cap)
    }

    /// Ingest one packet's worth of W-lane vector pairs — the columnar
    /// counterpart of [`Self::ingest_pairs`], sharing the pacing,
    /// analyzer, crossbar, FPE/BPE timing and stats machinery; at
    /// `W = 1` it is byte-identical to the scalar path.  Always runs
    /// on the serial reference engine (the sharded engine's ownership
    /// seams are unchanged by lane width; vector sharding can reuse
    /// them later).
    fn ingest_vector_range(
        &mut self,
        batch: &VectorBatch,
        range: std::ops::Range<usize>,
        eot: bool,
        header_delay: Cycles,
        out: &mut VectorSink,
    ) {
        assert_eq!(
            batch.lanes(),
            self.lanes,
            "batch lane width does not match the tree's configured width"
        );
        let w = self.lanes;
        self.account_packet_header();

        for i in range {
            let key = batch.key(i);
            let lanes = batch.lane_slice(i);
            let el = batch.encoded_len_pair(i);
            self.stats.bytes_in += el as u64;
            self.bytes_arrived += el as u64;
            self.stats.pairs_in += 1;
            let arrive = self.arrival_cycle() + header_delay;
            let g = self.analyzer.classify_parts(key.len(), el);
            let deliver = self.crossbar.route(arrive, g);
            self.evict_scratch.clear();
            let forwarded =
                self.fpes[g].offer_lanes(deliver, key, lanes, self.op, &mut self.evict_scratch);
            if let Some(ready) = forwarded {
                let (ek, ehash) = self.evict_scratch.keys[0];
                match &mut self.bpe {
                    Some(bpe) => {
                        let granted = self.scheduler.grant_single(g);
                        debug_assert_eq!(granted, g);
                        self.overflow_scratch.clear();
                        let overflow = bpe.offer_lanes_hashed(
                            ready,
                            g,
                            (ek, ehash),
                            self.evict_scratch.lane_slice(0, w),
                            self.op,
                            &mut self.overflow_scratch,
                        );
                        if overflow.is_some() {
                            let (ok, _) = self.overflow_scratch.keys[0];
                            let olanes = self.overflow_scratch.lane_slice(0, w);
                            self.stats.pairs_out_stream += 1;
                            self.stats.bytes_out += crate::protocol::vector::encoded_vec_len(
                                ok.len(),
                                w,
                                crate::protocol::vector::lane_value_width(olanes),
                            ) as u64;
                            out.forwarded.push(ok, olanes);
                        }
                    }
                    None => {
                        let elanes = self.evict_scratch.lane_slice(0, w);
                        self.stats.pairs_out_stream += 1;
                        self.stats.bytes_out += crate::protocol::vector::encoded_vec_len(
                            ek.len(),
                            w,
                            crate::protocol::vector::lane_value_width(elanes),
                        ) as u64;
                        out.forwarded.push(ek, elanes);
                    }
                }
            }
        }

        if eot {
            self.eot_seen += 1;
            if self.eot_seen >= self.children {
                self.flush_vector_into(out);
            }
        }
        self.roll_stats();
    }

    /// End-of-tree flush of a W-lane tree: every engine drains
    /// columnar into the sink; byte/pair accounting mirrors
    /// [`Self::flush_into`].
    fn flush_vector_into(&mut self, out: &mut VectorSink) {
        let w = self.lanes;
        out.flushes += 1;
        let start = out.flushed.len();
        let mut flush_cycles: Cycles = 0;
        for f in &mut self.fpes {
            out.scratch_keys.clear();
            out.scratch_vals.clear();
            flush_cycles += f.flush_lanes_into(&mut out.scratch_keys, &mut out.scratch_vals);
            for (j, &k) in out.scratch_keys.iter().enumerate() {
                out.flushed.push(k, &out.scratch_vals[j * w..(j + 1) * w]);
            }
        }
        if let Some(bpe) = &mut self.bpe {
            out.scratch_keys.clear();
            out.scratch_vals.clear();
            flush_cycles += bpe.flush_lanes_into(&mut out.scratch_keys, &mut out.scratch_vals);
            for (j, &k) in out.scratch_keys.iter().enumerate() {
                out.flushed.push(k, &out.scratch_vals[j * w..(j + 1) * w]);
            }
        }
        self.stats.flush_cycles += flush_cycles;
        let flushed_now = out.flushed.len() - start;
        self.stats.pairs_out_flush += flushed_now as u64;
        self.stats.bytes_out += (start..out.flushed.len())
            .map(|i| out.flushed.encoded_len_pair(i) as u64)
            .sum::<u64>();
        self.eot_seen = 0;
    }

    /// Account trailing per-packet header overhead on the output side:
    /// streamed-out pairs are packed into MTU-sized packets downstream
    /// (W-lane trees pack into per-W packet budgets; at `W = 1` this
    /// is exactly the scalar packetization).
    fn finalize_output_bytes(&mut self) {
        let payload = self.stats.bytes_out;
        let pkts = payload.div_ceil(max_vec_payload(self.lanes) as u64).max(
            (self.stats.pairs_out_stream + self.stats.pairs_out_flush > 0) as u64,
        );
        self.stats.bytes_out = payload + pkts * (HEADER_OVERHEAD + vec_fixed_len(self.lanes)) as u64;
    }

    /// Whether this chunk sequence would trigger an end-of-tree flush
    /// anywhere but at the very last chunk.  The sharded engine defers
    /// its single flush to the merge stage; a mid-stream flush resets
    /// table state between pairs and must take the serial path.
    fn flush_splits_stream(&self, chunks: &[(&[KvPair], bool)]) -> bool {
        let mut eot_seen = self.eot_seen;
        for (i, &(_, eot)) in chunks.iter().enumerate() {
            if eot {
                eot_seen += 1;
                if eot_seen >= self.children {
                    if i + 1 != chunks.len() {
                        return true;
                    }
                    eot_seen = 0;
                }
            }
        }
        false
    }

    /// Sharded ingest of a whole chunk sequence (see `switch::parallel`
    /// for why this is byte-identical to calling
    /// [`Self::ingest_pairs`] per chunk).
    fn ingest_chunks_sharded(
        &mut self,
        chunks: &[(&[KvPair], bool)],
        header_delay: Cycles,
        shards: usize,
        out: &mut IngestSink,
    ) {
        let n_groups = self.fpes.len();
        // Front end (serial): byte pacing + analyzer accounting; every
        // pair is stamped with its global sequence number and arrival
        // cycle and binned by group.
        let mut jobs: Vec<Vec<JobPair>> = (0..n_groups).map(|_| Vec::new()).collect();
        let mut seq: u64 = 0;
        let mut eots: u32 = 0;
        for &(pairs, eot) in chunks {
            self.account_packet_header();
            for p in pairs {
                let (g, arrive) = self.account_pair(p, header_delay);
                jobs[g].push(JobPair {
                    seq,
                    arrive,
                    pair: *p,
                });
                seq += 1;
            }
            if eot {
                eots += 1;
            }
        }
        // Distribute disjoint {FPE, BPE region, crossbar output} shards
        // round-robin across workers (spreads the skewed group weights
        // better than contiguous ranges).
        let op = self.op;
        let evict_old = self
            .bpe
            .as_ref()
            .map(|b| b.eviction() == EvictionPolicy::EvictOld)
            .unwrap_or(false);
        let mut regions: Vec<Option<&mut HashTable>> = match self.bpe.as_mut() {
            Some(b) => b.regions_mut().iter_mut().map(Some).collect(),
            None => (0..n_groups).map(|_| None).collect(),
        };
        let mut per_worker: Vec<Vec<WorkerGroup<'_>>> =
            (0..shards).map(|_| Vec::new()).collect();
        for ((g, fpe), job) in self.fpes.iter_mut().enumerate().zip(jobs) {
            per_worker[g % shards].push(WorkerGroup {
                group: g,
                job,
                fpe,
                region: regions[g].take(),
                port: self.crossbar.port_view(g),
                op,
                evict_old,
            });
        }
        let mut outputs = run_workers(per_worker);
        outputs.sort_by_key(|o| o.group);
        // Merge (serial, deterministic): fold the per-output crossbar
        // views and BPE probe counts back in, replay the shared BPE
        // timing in global eviction order, then emit downstream pairs
        // in the serial path's order.
        for o in &outputs {
            self.crossbar.absorb(o.group, o.port);
            if let Some(b) = self.bpe.as_mut() {
                b.absorb_probe_counts(o.bpe_aggregated, o.bpe_inserted, o.bpe_overflowed);
            }
        }
        let evict_streams: Vec<&[(u64, (usize, Cycles))]> =
            outputs.iter().map(|o| o.evicts.as_slice()).collect();
        let merged_evicts = merge_by_seq(&evict_streams);
        if let Some(b) = self.bpe.as_mut() {
            for &(_, (group, ready)) in &merged_evicts {
                let granted = self.scheduler.grant_single(group);
                debug_assert_eq!(granted, group);
                b.replay_timing(ready);
            }
        }
        let emission_streams: Vec<&[(u64, KvPair)]> =
            outputs.iter().map(|o| o.emissions.as_slice()).collect();
        let merged_emissions = merge_by_seq(&emission_streams);
        for (_, pair) in merged_emissions {
            self.emit_pair(pair, out);
        }
        // End-of-tree flushes — by the `flush_splits_stream`
        // precondition, at most one fires, and only at the stream end.
        for _ in 0..eots {
            self.eot_seen += 1;
            if self.eot_seen >= self.children {
                self.flush_into(out);
            }
        }
        self.roll_stats();
    }
}

/// The full switch.
pub struct SwitchAggSwitch {
    cfg: SwitchConfig,
    pub header_extract: HeaderExtract,
    pub forwarding: Forwarding,
    config_module: ConfigModule,
    trees: BTreeMap<TreeId, TreeEngine>,
    /// Per-tree value lane width (W); absent = 1 (scalar).  Announced
    /// via [`Self::configure_vector`] and applied at engine (re)build.
    lane_width: BTreeMap<TreeId, usize>,
    /// Exactly-once admission state for reliable streams, one window
    /// per `(tree, child port)` (see `switch::reliability`); created
    /// lazily on the first reliable packet of a stream.
    dedup: BTreeMap<(TreeId, u16), DedupWindow>,
    /// Window every dedup bitmap is sized from — the same [`RelWindow`]
    /// the session config hands its senders, so the two ends cannot
    /// disagree.
    rel_window: RelWindow,
    /// How acks fill their credit field (constant window vs
    /// FIFO-backpressure scaled).
    credit_policy: CreditPolicy,
    /// Per-tree job epoch (incarnation fence): reliable packets whose
    /// rel header carries another epoch are dropped at admission.
    /// Absent = 0, the initial incarnation.
    epochs: BTreeMap<TreeId, u16>,
    /// Per-tree count of epoch-fenced packets.  Simulator accounting:
    /// unlike `epochs`/`dedup`, this survives [`Self::crash`].
    stale_epoch: BTreeMap<TreeId, u64>,
    /// Reused sink for the stream entry points.
    sink: IngestSink,
}

impl SwitchAggSwitch {
    pub fn new(cfg: SwitchConfig) -> Self {
        Self {
            cfg,
            header_extract: HeaderExtract::new(),
            forwarding: Forwarding::new(),
            config_module: ConfigModule::new(),
            trees: BTreeMap::new(),
            lane_width: BTreeMap::new(),
            dedup: BTreeMap::new(),
            rel_window: RelWindow::default(),
            credit_policy: CreditPolicy::default(),
            epochs: BTreeMap::new(),
            stale_epoch: BTreeMap::new(),
            sink: IngestSink::new(),
        }
    }

    /// Size future dedup windows from `w` (the session's shared
    /// [`RelWindow`]).  Must precede the first reliable packet — live
    /// bitmaps cannot be resized without corrupting their streams.
    pub fn set_rel_window(&mut self, w: RelWindow) {
        assert!(
            self.dedup.is_empty() || w == self.rel_window,
            "reliable window must be set before the first reliable packet"
        );
        self.rel_window = w;
    }

    /// Select how acks advertise credit (takes effect immediately;
    /// the default [`CreditPolicy::WindowOnly`] is the PR 4 behavior).
    pub fn set_credit_policy(&mut self, policy: CreditPolicy) {
        self.credit_policy = policy;
    }

    /// The tree's current epoch (0 until [`Self::begin_epoch`] moves
    /// it).
    pub fn tree_epoch(&self, tree: TreeId) -> u16 {
        self.epochs.get(&tree).copied().unwrap_or(0)
    }

    /// Enter a new incarnation of one tree's job: the controller bumped
    /// the epoch (after a restart, or a membership re-plan), so every
    /// reliable sequence space of the tree restarts — its dedup windows
    /// are discarded and packets still carrying an older epoch are
    /// fenced at admission from now on.  The caller is responsible for
    /// having re-applied the tree's Configure first (engines rebuild
    /// there); epochs may repeat (idempotent re-push) but never regress.
    pub fn begin_epoch(&mut self, tree: TreeId, epoch: u16) {
        let cur = self.tree_epoch(tree);
        assert!(epoch >= cur, "epoch must not regress ({epoch} < {cur})");
        self.epochs.insert(tree, epoch);
        self.dedup.retain(|(t, _), _| *t != tree);
    }

    /// Simulate a switch crash: all soft state dies — aggregation
    /// engines (FPE/BPE contents), tree configuration, dedup windows,
    /// epoch registers, pending sink output.  What survives is what a
    /// real device keeps across a power cycle: the static `cfg`
    /// (hardware shape), the session's `rel_window`/`credit_policy`
    /// (re-pushed control plane would restore them anyway), and the
    /// stale-epoch counters (simulator accounting).  The controller
    /// brings the device back by re-sending Configure and then
    /// [`Self::begin_epoch`] with the bumped epoch.
    pub fn crash(&mut self) {
        self.header_extract = HeaderExtract::new();
        self.forwarding = Forwarding::new();
        self.config_module = ConfigModule::new();
        self.trees.clear();
        self.lane_width.clear();
        self.dedup.clear();
        self.epochs.clear();
        self.sink.clear();
    }

    pub fn config(&self) -> &SwitchConfig {
        &self.cfg
    }

    /// Apply a Configure packet (§4.2.2).  Memory is re-partitioned
    /// among all configured trees per the active [`MemoryPolicy`]
    /// (even by default, demand-weighted per §7 if hints were
    /// announced); engines are (re)built, so configuration must
    /// precede data for those trees.
    pub fn configure(&mut self, trees: &[TreeConfig]) {
        for t in trees {
            self.lane_width.insert(t.tree, 1);
        }
        self.rebuild_engines(trees);
    }

    /// [`Self::configure`] for trees whose values are W-lane vectors
    /// (`lanes ≥ 1`; 1 is exactly the scalar configuration): every FPE
    /// table and BPE region for the listed trees is built with a
    /// stride-`lanes` value buffer, and ingest goes through the
    /// [`Self::ingest_vector_stream`] family.  Trees configured
    /// earlier keep their own lane widths.
    pub fn configure_vector(&mut self, trees: &[TreeConfig], lanes: usize) {
        assert!(
            (1..=crate::protocol::MAX_LANES).contains(&lanes),
            "lane width {lanes} out of range"
        );
        for t in trees {
            self.lane_width.insert(t.tree, lanes);
        }
        self.rebuild_engines(trees);
    }

    /// Rebuild engines for all configured trees with their new memory
    /// shares (and per-tree lane widths).
    fn rebuild_engines(&mut self, trees: &[TreeConfig]) {
        self.config_module.apply(trees);
        let ids: Vec<TreeId> = self.config_module.tree_ids().collect();
        // A rebuild starts every configured tree's job from scratch, so
        // its reliable sequence spaces restart too — stale windows
        // would silently swallow a fresh stream as "duplicates".
        self.dedup.retain(|(t, _), _| !ids.contains(t));
        for id in ids {
            let tc = self.config_module.get(id).unwrap().clone();
            let fpe_share = self.config_module.memory_share_for(id, self.cfg.fpe_total_mem);
            let bpe_share = self
                .cfg
                .bpe_mem
                .map(|m| self.config_module.memory_share_for(id, m));
            let lanes = *self.lane_width.get(&id).unwrap_or(&1);
            self.forwarding.install_tree_parent(id, tc.parent_port);
            self.trees.insert(
                id,
                TreeEngine::new(&self.cfg, tc.op, tc.children, fpe_share, bpe_share, lanes),
            );
        }
    }

    /// Announce a tree's relative memory demand (application hint, §7
    /// "Memory Utilization"); takes effect at the next `configure`.
    pub fn set_memory_policy(&mut self, policy: crate::switch::config::MemoryPolicy) {
        self.config_module.policy = policy;
    }

    /// Select the ingest execution engine (serial reference or the
    /// group-sharded worker pool); takes effect immediately and does
    /// not change outputs or stats (see `switch::parallel`).
    pub fn set_parallelism(&mut self, parallelism: Parallelism) {
        self.cfg.parallelism = parallelism;
    }

    /// Set a tree's demand weight (used by the Weighted policy).
    pub fn set_tree_weight(&mut self, tree: TreeId, weight: u64) {
        self.config_module.set_weight(tree, weight);
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Ingest one aggregation packet for its tree, appending outputs to
    /// a caller-owned (reusable) sink.
    pub fn ingest_into(&mut self, pkt: &AggregationPacket, sink: &mut IngestSink) {
        let engine = self
            .trees
            .get_mut(&pkt.tree)
            .unwrap_or_else(|| panic!("tree {} not configured", pkt.tree));
        engine.ingest_pairs(&pkt.pairs, pkt.eot, self.cfg.delays.header_analyzer, sink);
    }

    /// Ingest one W-lane vector aggregation packet for its tree,
    /// appending outputs to a caller-owned (reusable) [`VectorSink`].
    pub fn ingest_vector_packet_into(
        &mut self,
        pkt: &crate::protocol::VectorAggregationPacket,
        sink: &mut VectorSink,
    ) {
        self.ingest_vector_range_for(pkt.tree, &pkt.batch, 0..pkt.batch.len(), pkt.eot, sink);
    }

    /// Admit one reliable packet's `(child, seq, eot)` through its
    /// dedup window.  Returns `(ingest_payload, fire_eot)` — whether
    /// the pairs are new (retransmissions and wire duplicates are
    /// dropped here, before any engine sees them) and whether the
    /// deferred end-of-transmission signal became deliverable — plus
    /// the ack to send back.  Shared by the scalar and vector reliable
    /// entry points so exactly-once semantics cannot drift between
    /// them.
    fn admit_reliable(
        &mut self,
        tree: TreeId,
        rel: crate::protocol::RelHeader,
        eot: bool,
    ) -> (bool, bool, AggAckPacket) {
        let cur_epoch = self.tree_epoch(tree);
        if rel.epoch != cur_epoch {
            // Epoch fence: traffic from a dead incarnation must neither
            // reach an engine nor perturb any window.  The ack restates
            // the current epoch with the (possibly fresh) window state,
            // so a live-but-stale sender learns it must rebase.
            *self.stale_epoch.entry(tree).or_insert(0) += 1;
            let (cum_seq, credit) = match self.dedup.get(&(tree, rel.child)) {
                Some(w) => (w.cum_seq(), w.credit()),
                None => (0, self.rel_window.get() as u16),
            };
            let ack = AggAckPacket {
                tree,
                child: rel.child,
                epoch: cur_epoch,
                cum_seq,
                credit,
            };
            return (false, false, ack);
        }
        let window = self.rel_window;
        let w = self
            .dedup
            .entry((tree, rel.child))
            .or_insert_with(|| DedupWindow::sized(window));
        let (is_new, fire) = match w.offer(rel.seq, eot) {
            Admit::New => (true, w.take_ready_eot()),
            Admit::Duplicate | Admit::OutOfWindow => (false, false),
        };
        let cum_seq = w.cum_seq();
        let mut credit = w.credit();
        if matches!(self.credit_policy, CreditPolicy::Backpressure) {
            if let Some(e) = self.trees.get(&tree) {
                let (depth, cap) = e.input_queue();
                credit = backpressure_credit(credit, depth, cap);
            }
        }
        let ack = AggAckPacket {
            tree,
            child: rel.child,
            epoch: cur_epoch,
            cum_seq,
            credit,
        };
        (is_new, fire, ack)
    }

    /// Ingest one batch of reliable aggregation packets (one tree),
    /// exactly-once: every packet passes its `(tree, child)` dedup
    /// window first, admitted chunks run through the configured engine
    /// (serial or sharded — the whole batch goes down the chunk-
    /// sequence path, so a sharded switch shards reliable ingest too),
    /// and one cumulative-ack/credit record per input packet is
    /// returned for the senders.  EoT flags are deferred by the window
    /// until the child's stream prefix is complete, so a flush can
    /// never strand late retransmissions in the tables.
    pub fn ingest_reliable_batch(
        &mut self,
        tree: TreeId,
        pkts: &[&AggregationPacket],
        sink: &mut IngestSink,
    ) -> Vec<AggAckPacket> {
        let mut acks = Vec::with_capacity(pkts.len());
        let mut chunks: Vec<(&[KvPair], bool)> = Vec::with_capacity(pkts.len());
        for pkt in pkts {
            assert_eq!(pkt.tree, tree, "reliable batch must be single-tree");
            let rel = pkt.rel.expect("reliable ingest requires a rel header");
            let (is_new, fire, ack) = self.admit_reliable(tree, rel, pkt.eot);
            if is_new {
                chunks.push((pkt.pairs.as_slice(), fire));
            }
            acks.push(ack);
        }
        if !chunks.is_empty() {
            self.ingest_chunk_seq(tree, &chunks, sink);
        }
        acks
    }

    /// Single-packet reliable ingest — the per-arrival entry point for
    /// the event-driven co-simulation (`framework::transport`), which
    /// reacts to one `NetSim` delivery at a time: identical admission
    /// and engine path to a one-element [`Self::ingest_reliable_batch`],
    /// but with no per-call ack/chunk heap allocation (the chunk
    /// sequence lives on the stack), so the delivery hot loop stays
    /// allocation-free.
    pub fn ingest_reliable_one(
        &mut self,
        tree: TreeId,
        pkt: &AggregationPacket,
        sink: &mut IngestSink,
    ) -> AggAckPacket {
        assert_eq!(pkt.tree, tree, "reliable ingest must be single-tree");
        let rel = pkt.rel.expect("reliable ingest requires a rel header");
        let (is_new, fire, ack) = self.admit_reliable(tree, rel, pkt.eot);
        if is_new {
            self.ingest_chunk_seq(tree, &[(pkt.pairs.as_slice(), fire)], sink);
        }
        ack
    }

    /// The W-lane counterpart of [`Self::ingest_reliable_one`].
    pub fn ingest_vector_reliable_one(
        &mut self,
        tree: TreeId,
        pkt: &crate::protocol::VectorAggregationPacket,
        sink: &mut VectorSink,
    ) -> AggAckPacket {
        assert_eq!(pkt.tree, tree, "reliable ingest must be single-tree");
        let rel = pkt.rel.expect("reliable ingest requires a rel header");
        let (is_new, fire, ack) = self.admit_reliable(tree, rel, pkt.eot);
        if is_new {
            self.ingest_vector_range_for(tree, &pkt.batch, 0..pkt.batch.len(), fire, sink);
        }
        ack
    }

    /// The W-lane counterpart of [`Self::ingest_reliable_batch`]:
    /// admitted vector packets take the serial columnar path (vector
    /// ingest is always serial; see [`Self::ingest_vector_stream_into`]).
    pub fn ingest_vector_reliable_batch(
        &mut self,
        tree: TreeId,
        pkts: &[&crate::protocol::VectorAggregationPacket],
        sink: &mut VectorSink,
    ) -> Vec<AggAckPacket> {
        let mut acks = Vec::with_capacity(pkts.len());
        for pkt in pkts {
            assert_eq!(pkt.tree, tree, "reliable batch must be single-tree");
            let rel = pkt.rel.expect("reliable ingest requires a rel header");
            let (is_new, fire, ack) = self.admit_reliable(tree, rel, pkt.eot);
            if is_new {
                self.ingest_vector_range_for(tree, &pkt.batch, 0..pkt.batch.len(), fire, sink);
            }
            acks.push(ack);
        }
        acks
    }

    /// Aggregate dedup counters over all of `tree`'s child windows.
    pub fn dedup_stats(&self, tree: TreeId) -> DedupStats {
        let mut out = DedupStats::default();
        for ((t, _), w) in &self.dedup {
            if *t == tree {
                let s = w.stats();
                out.admitted += s.admitted;
                out.dup_drops += s.dup_drops;
                out.out_of_window += s.out_of_window;
            }
        }
        out.stale_epoch_drops = self.stale_epoch.get(&tree).copied().unwrap_or(0);
        out
    }

    /// Ingest one aggregation packet, returning owned output buffers
    /// (compatibility wrapper; hot loops should prefer
    /// [`Self::ingest_into`] with a reused [`IngestSink`]).
    pub fn ingest(&mut self, pkt: &AggregationPacket) -> IngestOutput {
        let mut sink = IngestSink::new();
        self.ingest_into(pkt, &mut sink);
        IngestOutput {
            forwarded: sink.forwarded,
            flushed: (sink.flushes > 0).then_some(sink.flushed),
        }
    }

    /// Capacity of the internal reusable ingest sink — lets tests
    /// assert that the steady-state stream path stops allocating.
    pub fn sink_capacity(&self) -> usize {
        self.sink.capacity()
    }

    /// Convenience: run a whole pair stream (chunked into MTU-sized
    /// packets on the fly) through one tree; EoT is counted once per
    /// `children`, so pass the merged stream of all children — or use
    /// [`Self::ingest_child_streams`].
    pub fn ingest_stream(&mut self, tree: TreeId, op: AggOp, pairs: &[KvPair]) -> Vec<KvPair> {
        let _ = op; // the tree's configured op applies; kept for API compat
        let mut sink = std::mem::take(&mut self.sink);
        sink.clear();
        let children = self
            .config_module
            .get(tree)
            .map(|t| t.children)
            .unwrap_or(1);
        // Merged stream: emit children EoTs by splitting at the end
        // (Theorem 2.1: merging flows preserves the reduction ratio).
        if matches!(self.cfg.parallelism, Parallelism::Serial) {
            // Serial reference: stream the chunks straight through —
            // no chunk list, no per-packet allocation.
            let mut chunks = MtuChunks::new(pairs);
            while let Some((chunk, _)) = chunks.next_chunk() {
                self.ingest_pairs_for(tree, chunk, false, &mut sink);
            }
            for _ in 0..children {
                self.ingest_pairs_for(tree, &[], true, &mut sink);
            }
        } else {
            let empty: &[KvPair] = &[];
            let mut chunk_seq: Vec<(&[KvPair], bool)> = Vec::new();
            let mut chunks = MtuChunks::new(pairs);
            while let Some((chunk, _)) = chunks.next_chunk() {
                chunk_seq.push((chunk, false));
            }
            for _ in 0..children {
                chunk_seq.push((empty, true));
            }
            self.ingest_chunk_seq(tree, &chunk_seq, &mut sink);
        }
        self.finalize(tree);
        let out = sink_to_vec(&sink);
        self.sink = sink;
        out
    }

    /// Ingest per-child streams interleaved round-robin packet-wise —
    /// the many-to-one pattern of Fig. 1.
    pub fn ingest_child_streams(
        &mut self,
        tree: TreeId,
        op: AggOp,
        streams: &[Vec<KvPair>],
    ) -> Vec<KvPair> {
        let _ = op; // the tree's configured op applies; kept for API compat
        let mut sink = std::mem::take(&mut self.sink);
        sink.clear();
        let mut chunkers: Vec<MtuChunks<'_>> =
            streams.iter().map(|s| MtuChunks::new(s)).collect();
        if matches!(self.cfg.parallelism, Parallelism::Serial) {
            // Serial reference: stream the interleaved chunks straight
            // through — no chunk list, no per-packet allocation.
            loop {
                let mut progressed = false;
                for c in chunkers.iter_mut() {
                    if let Some((chunk, last)) = c.next_chunk() {
                        progressed = true;
                        self.ingest_pairs_for(tree, chunk, last, &mut sink);
                    }
                }
                if !progressed {
                    break;
                }
            }
        } else {
            let mut chunk_seq: Vec<(&[KvPair], bool)> = Vec::new();
            loop {
                let mut progressed = false;
                for c in chunkers.iter_mut() {
                    if let Some((chunk, last)) = c.next_chunk() {
                        progressed = true;
                        chunk_seq.push((chunk, last));
                    }
                }
                if !progressed {
                    break;
                }
            }
            self.ingest_chunk_seq(tree, &chunk_seq, &mut sink);
        }
        self.finalize(tree);
        let out = sink_to_vec(&sink);
        self.sink = sink;
        out
    }

    /// Run a whole W-lane vector stream (chunked into per-W MTU-sized
    /// packets on the fly) through one tree, appending to a
    /// caller-owned (reusable) [`VectorSink`] — the vector counterpart
    /// of [`Self::ingest_stream`].  EoT is counted once per child, so
    /// pass the merged stream of all children — or use
    /// [`Self::ingest_vector_child_streams_into`].  Always runs the
    /// serial reference engine.
    pub fn ingest_vector_stream_into(
        &mut self,
        tree: TreeId,
        batch: &VectorBatch,
        sink: &mut VectorSink,
    ) {
        let children = self
            .config_module
            .get(tree)
            .map(|t| t.children)
            .unwrap_or(1);
        let mut chunks = VectorChunks::new(batch);
        while let Some((range, _)) = chunks.next_chunk() {
            self.ingest_vector_range_for(tree, batch, range, false, sink);
        }
        for _ in 0..children {
            self.ingest_vector_range_for(tree, batch, 0..0, true, sink);
        }
        self.finalize(tree);
    }

    /// [`Self::ingest_vector_stream_into`] into a fresh batch
    /// (forwarded stream followed by the end-of-tree flush).
    pub fn ingest_vector_stream(&mut self, tree: TreeId, batch: &VectorBatch) -> VectorBatch {
        let mut sink = VectorSink::new(batch.lanes());
        self.ingest_vector_stream_into(tree, batch, &mut sink);
        vector_sink_to_batch(&sink)
    }

    /// Ingest per-child W-lane streams interleaved round-robin
    /// packet-wise — the many-to-one pattern of Fig. 1, vector
    /// payloads (allreduce fan-in).
    pub fn ingest_vector_child_streams_into(
        &mut self,
        tree: TreeId,
        streams: &[VectorBatch],
        sink: &mut VectorSink,
    ) {
        let mut chunkers: Vec<VectorChunks<'_>> =
            streams.iter().map(VectorChunks::new).collect();
        loop {
            let mut progressed = false;
            for (s, c) in streams.iter().zip(chunkers.iter_mut()) {
                if let Some((range, last)) = c.next_chunk() {
                    progressed = true;
                    self.ingest_vector_range_for(tree, s, range, last, sink);
                }
            }
            if !progressed {
                break;
            }
        }
        self.finalize(tree);
    }

    /// [`Self::ingest_vector_child_streams_into`] into a fresh batch.
    pub fn ingest_vector_child_streams(
        &mut self,
        tree: TreeId,
        streams: &[VectorBatch],
    ) -> VectorBatch {
        let lanes = streams.first().map(|b| b.lanes()).unwrap_or(1);
        let mut sink = VectorSink::new(lanes);
        self.ingest_vector_child_streams_into(tree, streams, &mut sink);
        vector_sink_to_batch(&sink)
    }

    /// Core columnar ingest: one per-W MTU chunk of one tree's vector
    /// traffic, on the serial reference path.
    fn ingest_vector_range_for(
        &mut self,
        tree: TreeId,
        batch: &VectorBatch,
        range: std::ops::Range<usize>,
        eot: bool,
        sink: &mut VectorSink,
    ) {
        let engine = self
            .trees
            .get_mut(&tree)
            .unwrap_or_else(|| panic!("tree {tree} not configured"));
        engine.ingest_vector_range(batch, range, eot, self.cfg.delays.header_analyzer, sink);
    }

    /// Core slice-based ingest (no packet object): one MTU chunk of one
    /// tree's traffic, on the serial reference path.
    fn ingest_pairs_for(
        &mut self,
        tree: TreeId,
        pairs: &[KvPair],
        eot: bool,
        sink: &mut IngestSink,
    ) {
        let engine = self
            .trees
            .get_mut(&tree)
            .unwrap_or_else(|| panic!("tree {tree} not configured"));
        engine.ingest_pairs(pairs, eot, self.cfg.delays.header_analyzer, sink);
    }

    /// Sharded-engine ingest of a whole chunk sequence for one tree.
    /// The sharded engine requires the (at most one) end-of-tree flush
    /// to land on the final chunk; sequences that flush mid-stream
    /// silently take the serial loop instead.
    fn ingest_chunk_seq(
        &mut self,
        tree: TreeId,
        chunks: &[(&[KvPair], bool)],
        sink: &mut IngestSink,
    ) {
        let header_delay = self.cfg.delays.header_analyzer;
        let parallelism = self.cfg.parallelism;
        let engine = self
            .trees
            .get_mut(&tree)
            .unwrap_or_else(|| panic!("tree {tree} not configured"));
        match parallelism {
            Parallelism::Sharded(n) if !engine.flush_splits_stream(chunks) => {
                engine.ingest_chunks_sharded(chunks, header_delay, n.max(1), sink);
            }
            _ => {
                // Count the silent fallback so benchmarks can detect
                // serial numbers recorded under a sharded config.
                if !matches!(parallelism, Parallelism::Serial) {
                    engine.stats.fallback_serial += 1;
                }
                for &(pairs, eot) in chunks {
                    engine.ingest_pairs(pairs, eot, header_delay, sink);
                }
            }
        }
    }

    /// Close output byte accounting (packetization of the out stream).
    pub fn finalize(&mut self, tree: TreeId) {
        if let Some(e) = self.trees.get_mut(&tree) {
            e.finalize_output_bytes();
        }
    }

    pub fn stats(&self, tree: TreeId) -> Option<&SwitchStats> {
        self.trees.get(&tree).map(|e| &e.stats)
    }

    /// Average measured FPE pair latency in cycles (Table 3 check).
    pub fn avg_fpe_latency(&self, tree: TreeId) -> f64 {
        let e = &self.trees[&tree];
        let pairs: u64 = e.fpes.iter().map(|f| f.aggregated + f.inserted + f.evicted).sum();
        let cyc: u64 = e.fpes.iter().map(|f| f.latency_cycles).sum();
        if pairs == 0 {
            0.0
        } else {
            cyc as f64 / pairs as f64
        }
    }

    /// Sum of BPE DRAM commands and stall cycles (overlap diagnostics).
    pub fn bpe_dram_stats(&self, tree: TreeId) -> Option<(u64, Cycles)> {
        self.trees[&tree].bpe.as_ref().map(|b| b.dram_stats())
    }
}

/// Concatenate a sink's stream + flush output (flushes only happen
/// after the final EoT, so this preserves emission order).
fn sink_to_vec(sink: &IngestSink) -> Vec<KvPair> {
    let mut out = Vec::with_capacity(sink.forwarded.len() + sink.flushed.len());
    out.extend_from_slice(&sink.forwarded);
    out.extend_from_slice(&sink.flushed);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::packet::TreeConfig;
    use crate::util::rng::Pcg32;

    fn configured_switch(fpe_mem: u64, bpe_mem: Option<u64>, children: u16) -> SwitchAggSwitch {
        let cfg = SwitchConfig::scaled(fpe_mem, bpe_mem);
        let mut sw = SwitchAggSwitch::new(cfg);
        sw.configure(&[TreeConfig {
            tree: TreeId(1),
            children,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        sw
    }

    fn pairs(n: usize, distinct: u64, seed: u64) -> Vec<KvPair> {
        let mut rng = Pcg32::new(seed);
        (0..n)
            .map(|_| {
                let id = rng.gen_range_u64(distinct);
                KvPair::new(Key::from_id(id, 16 + (id % 49) as usize), 1)
            })
            .collect()
    }

    #[test]
    fn sum_is_conserved_through_the_switch() {
        let mut sw = configured_switch(64 << 10, Some(1 << 20), 1);
        let input = pairs(20_000, 500, 42);
        let want: Value = input.iter().map(|p| p.value).sum();
        let out = sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
        let got: Value = out.iter().map(|p| p.value).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn keys_fully_aggregated_when_memory_sufficient() {
        let mut sw = configured_switch(4 << 20, Some(8 << 20), 1);
        let input = pairs(10_000, 100, 7);
        let out = sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
        // Every distinct key appears exactly once in the output.
        let mut seen = std::collections::HashMap::new();
        for p in &out {
            *seen.entry(p.key).or_insert(0u32) += 1;
        }
        assert!(seen.values().all(|&c| c == 1), "duplicate keys in output");
        assert_eq!(seen.len() as u64, 100);
        let s = sw.stats(TreeId(1)).unwrap();
        assert!(s.reduction_ratio() > 0.9, "r={}", s.reduction_ratio());
    }

    #[test]
    fn small_memory_reduces_reduction_ratio() {
        let big = {
            let mut sw = configured_switch(4 << 20, None, 1);
            sw.ingest_stream(TreeId(1), AggOp::Sum, &pairs(50_000, 20_000, 3));
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        let small = {
            let mut sw = configured_switch(16 << 10, None, 1);
            sw.ingest_stream(TreeId(1), AggOp::Sum, &pairs(50_000, 20_000, 3));
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        assert!(big > small, "big={big} small={small}");
    }

    #[test]
    fn multilevel_beats_single_level() {
        let input = pairs(60_000, 30_000, 9);
        let single = {
            let mut sw = configured_switch(32 << 10, None, 1);
            sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        let multi = {
            let mut sw = configured_switch(32 << 10, Some(4 << 20), 1);
            sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        assert!(multi > single + 0.2, "multi={multi} single={single}");
    }

    #[test]
    fn eot_from_all_children_triggers_flush() {
        let mut sw = configured_switch(1 << 20, Some(1 << 20), 3);
        let streams: Vec<Vec<KvPair>> =
            (0..3).map(|i| pairs(1000, 50, i as u64)).collect();
        let out = sw.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
        let s = sw.stats(TreeId(1)).unwrap();
        assert!(s.pairs_out_flush > 0);
        assert!(s.packets_in > 0);
        let want: Value = streams.iter().flatten().map(|p| p.value).sum();
        let got: Value = out.iter().map(|p| p.value).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn chunked_stream_ingest_matches_packetized_ingest() {
        // The zero-copy stream path must produce byte-for-byte the
        // same outputs and stats as ingesting materialized packets.
        let input = pairs(5_000, 700, 21);
        let mut chunked = configured_switch(16 << 10, Some(256 << 10), 1);
        let out_chunked = chunked.ingest_stream(TreeId(1), AggOp::Sum, &input);

        let mut packetized = configured_switch(16 << 10, Some(256 << 10), 1);
        let pkts = AggregationPacket::pack_stream(TreeId(1), AggOp::Sum, &input, false);
        let mut sink = IngestSink::new();
        for pkt in &pkts {
            packetized.ingest_into(pkt, &mut sink);
        }
        let eot = AggregationPacket {
            tree: TreeId(1),
            op: AggOp::Sum,
            eot: true,
            rel: None,
            pairs: vec![],
        };
        packetized.ingest_into(&eot, &mut sink);
        packetized.finalize(TreeId(1));
        let out_packetized = sink_to_vec(&sink);

        assert_eq!(out_chunked, out_packetized);
        let a = chunked.stats(TreeId(1)).unwrap();
        let b = packetized.stats(TreeId(1)).unwrap();
        assert_eq!((a.packets_in, a.bytes_in, a.bytes_out), (b.packets_in, b.bytes_in, b.bytes_out));
    }

    #[test]
    fn ingest_into_matches_ingest_wrapper() {
        let input = pairs(3_000, 200, 33);
        let pkts = AggregationPacket::pack_stream(TreeId(1), AggOp::Sum, &input, true);
        let mut a = configured_switch(16 << 10, Some(256 << 10), 1);
        let mut b = configured_switch(16 << 10, Some(256 << 10), 1);
        let mut sink = IngestSink::new();
        let mut via_wrapper: Vec<KvPair> = Vec::new();
        for pkt in &pkts {
            let r = a.ingest(pkt);
            via_wrapper.extend(r.forwarded);
            if let Some(f) = r.flushed {
                via_wrapper.extend(f);
            }
            b.ingest_into(pkt, &mut sink);
        }
        let via_sink = sink_to_vec(&sink);
        assert_eq!(via_wrapper, via_sink);
        assert_eq!(sink.flushes, 1);
    }

    #[test]
    fn fifo_full_ratio_is_small_at_line_rate() {
        let mut sw = configured_switch(256 << 10, Some(4 << 20), 1);
        let input = pairs(100_000, 50_000, 11);
        sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
        let s = sw.stats(TreeId(1)).unwrap();
        assert!(s.fifo_writes >= 100_000);
        assert!(
            s.fifo_full_ratio() < 0.01,
            "full ratio {} too high",
            s.fifo_full_ratio()
        );
    }

    #[test]
    fn two_trees_split_memory() {
        let cfg = SwitchConfig::scaled(64 << 10, None);
        let mut sw = SwitchAggSwitch::new(cfg);
        let mk = |id| TreeConfig {
            tree: TreeId(id),
            children: 1,
            parent_port: 0,
            op: AggOp::Sum,
        };
        sw.configure(&[mk(1), mk(2)]);
        assert_eq!(sw.n_trees(), 2);
        let input = pairs(30_000, 10_000, 5);
        let r2trees = {
            sw.ingest_stream(TreeId(1), AggOp::Sum, &input);
            sw.stats(TreeId(1)).unwrap().reduction_ratio()
        };
        let mut solo = SwitchAggSwitch::new(SwitchConfig::scaled(64 << 10, None));
        solo.configure(&[mk(1)]);
        solo.ingest_stream(TreeId(1), AggOp::Sum, &input);
        let r1tree = solo.stats(TreeId(1)).unwrap().reduction_ratio();
        assert!(
            r1tree > r2trees,
            "memory halving should hurt: solo={r1tree} shared={r2trees}"
        );
    }

    #[test]
    fn sharded_ingest_matches_serial_exactly() {
        // Same streams through the serial reference and the sharded
        // engine: outputs and every stat must be byte-identical.
        let streams: Vec<Vec<KvPair>> = (0..3).map(|i| pairs(4_000, 700, 11 + i)).collect();
        let mut serial = configured_switch(16 << 10, Some(256 << 10), 3);
        let out_serial = serial.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
        for shards in [1usize, 2, 4, 8] {
            let mut sharded = configured_switch(16 << 10, Some(256 << 10), 3);
            sharded.set_parallelism(crate::switch::parallel::Parallelism::Sharded(shards));
            let out_sharded = sharded.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
            assert_eq!(out_sharded, out_serial, "{shards} shards");
            let a = serial.stats(TreeId(1)).unwrap();
            let b = sharded.stats(TreeId(1)).unwrap();
            assert_eq!(
                format!("{a:?}"),
                format!("{b:?}"),
                "stats diverged at {shards} shards"
            );
            assert_eq!(
                serial.avg_fpe_latency(TreeId(1)),
                sharded.avg_fpe_latency(TreeId(1))
            );
            assert_eq!(
                serial.bpe_dram_stats(TreeId(1)),
                sharded.bpe_dram_stats(TreeId(1))
            );
        }
    }

    #[test]
    fn sharded_ingest_without_bpe_matches_serial() {
        let input = pairs(8_000, 3_000, 77);
        let mut serial = configured_switch(8 << 10, None, 1);
        let out_serial = serial.ingest_stream(TreeId(1), AggOp::Sum, &input);
        let mut sharded = configured_switch(8 << 10, None, 1);
        sharded.set_parallelism(crate::switch::parallel::Parallelism::Sharded(4));
        let out_sharded = sharded.ingest_stream(TreeId(1), AggOp::Sum, &input);
        assert_eq!(out_sharded, out_serial);
        let a = serial.stats(TreeId(1)).unwrap();
        let b = sharded.stats(TreeId(1)).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    #[should_panic(expected = "not configured")]
    fn unconfigured_tree_panics() {
        let mut sw = SwitchAggSwitch::new(SwitchConfig::default());
        let pkt = AggregationPacket {
            tree: TreeId(9),
            op: AggOp::Sum,
            eot: false,
            rel: None,
            pairs: vec![],
        };
        sw.ingest(&pkt);
    }

    /// Packetize a stream with reliability records (child, seq 1..).
    fn rel_packets(tree: TreeId, child: u16, pairs: &[KvPair]) -> Vec<AggregationPacket> {
        let mut pkts = AggregationPacket::pack_stream(tree, AggOp::Sum, pairs, true);
        for (i, p) in pkts.iter_mut().enumerate() {
            p.rel = Some(crate::protocol::RelHeader {
                child,
                epoch: 0,
                seq: i as u32 + 1,
            });
        }
        pkts
    }

    #[test]
    fn reliable_ingest_dedups_retransmissions() {
        let mut sw = configured_switch(16 << 10, Some(256 << 10), 1);
        let input = pairs(3_000, 500, 99);
        let want: Value = input.iter().map(|p| p.value).sum();
        let pkts = rel_packets(TreeId(1), 0, &input);
        let refs: Vec<&AggregationPacket> = pkts.iter().collect();
        let mut sink = IngestSink::new();
        let acks = sw.ingest_reliable_batch(TreeId(1), &refs, &mut sink);
        assert_eq!(acks.len(), pkts.len());
        assert_eq!(acks.last().unwrap().cum_seq, pkts.len() as u32);
        assert_eq!(sink.flushes, 1, "single child: EoT flushes once");
        let delivered = (sink.forwarded.len(), sink.flushed.len());
        let got: Value = sink_to_vec(&sink).iter().map(|p| p.value).sum();
        assert_eq!(got, want);

        // Retransmit the whole stream: every packet is a duplicate —
        // nothing reaches the engines, outputs and stats are unchanged.
        let stats_before = format!("{:?}", sw.stats(TreeId(1)).unwrap());
        let acks2 = sw.ingest_reliable_batch(TreeId(1), &refs, &mut sink);
        assert_eq!(acks2.last().unwrap().cum_seq, pkts.len() as u32);
        assert_eq!((sink.forwarded.len(), sink.flushed.len()), delivered);
        assert_eq!(format!("{:?}", sw.stats(TreeId(1)).unwrap()), stats_before);
        let d = sw.dedup_stats(TreeId(1));
        assert_eq!(d.admitted, pkts.len() as u64);
        assert_eq!(d.dup_drops, pkts.len() as u64);
    }

    #[test]
    fn reliable_one_matches_reliable_batch() {
        // The per-arrival entry point must be byte-identical to a
        // one-element batch: same acks, same outputs, same stats.
        let streams: Vec<Vec<KvPair>> = (0..2).map(|i| pairs(1_500, 200, 60 + i)).collect();
        let mut batch_sw = configured_switch(16 << 10, Some(256 << 10), 2);
        let mut one_sw = configured_switch(16 << 10, Some(256 << 10), 2);
        let mut batch_sink = IngestSink::new();
        let mut one_sink = IngestSink::new();
        for (c, s) in streams.iter().enumerate() {
            let pkts = rel_packets(TreeId(1), c as u16, s);
            for pkt in &pkts {
                let a = batch_sw.ingest_reliable_batch(TreeId(1), &[pkt], &mut batch_sink);
                let b = one_sw.ingest_reliable_one(TreeId(1), pkt, &mut one_sink);
                assert_eq!(a[0], b);
            }
        }
        assert_eq!(batch_sink.flushes, one_sink.flushes);
        assert_eq!(sink_to_vec(&batch_sink), sink_to_vec(&one_sink));
        batch_sw.finalize(TreeId(1));
        one_sw.finalize(TreeId(1));
        assert_eq!(
            format!("{:?}", batch_sw.stats(TreeId(1)).unwrap()),
            format!("{:?}", one_sw.stats(TreeId(1)).unwrap())
        );
        assert_eq!(batch_sw.dedup_stats(TreeId(1)), one_sw.dedup_stats(TreeId(1)));
    }

    #[test]
    fn reliable_ingest_defers_eot_across_reordering() {
        // Deliver each child's packets in reverse order: the EoT
        // packet arrives first, so the flush must wait until the
        // window below it fills — and fire exactly once per tree.
        let mut sw = configured_switch(64 << 10, Some(1 << 20), 2);
        let streams: Vec<Vec<KvPair>> = (0..2).map(|i| pairs(2_000, 300, 7 + i)).collect();
        let want: Value = streams.iter().flatten().map(|p| p.value).sum();
        let mut sink = IngestSink::new();
        for (c, s) in streams.iter().enumerate() {
            let pkts = rel_packets(TreeId(1), c as u16, s);
            let refs: Vec<&AggregationPacket> = pkts.iter().rev().collect();
            sw.ingest_reliable_batch(TreeId(1), &refs, &mut sink);
        }
        assert_eq!(sink.flushes, 1);
        let got: Value = sink_to_vec(&sink).iter().map(|p| p.value).sum();
        assert_eq!(got, want);
        assert_eq!(sw.dedup_stats(TreeId(1)).dup_drops, 0);
    }

    #[test]
    fn reconfigure_resets_reliable_sequence_spaces() {
        // Regression: a second job on a reconfigured tree restarts its
        // seq space at 1 — stale dedup windows must not swallow the
        // fresh stream as duplicates.
        let mut sw = configured_switch(64 << 10, Some(1 << 20), 1);
        let input = pairs(500, 80, 1);
        let want: Value = input.iter().map(|p| p.value).sum();
        let pkts = rel_packets(TreeId(1), 0, &input);
        let refs: Vec<&AggregationPacket> = pkts.iter().collect();
        let mut sink = IngestSink::new();
        sw.ingest_reliable_batch(TreeId(1), &refs, &mut sink);
        assert_eq!(sink.flushes, 1);

        // Reconfigure the same tree: fresh job, fresh seq space.
        sw.configure(&[TreeConfig {
            tree: TreeId(1),
            children: 1,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        let mut sink2 = IngestSink::new();
        let acks = sw.ingest_reliable_batch(TreeId(1), &refs, &mut sink2);
        assert_eq!(sink2.flushes, 1, "second job must flush again");
        assert_eq!(acks.last().unwrap().cum_seq, pkts.len() as u32);
        let got: Value = sink_to_vec(&sink2).iter().map(|p| p.value).sum();
        assert_eq!(got, want, "second job must admit the full stream");
    }

    #[test]
    fn fallback_serial_counter_fires_on_mid_stream_flush() {
        // children=1 with two EoT-carrying streams: the first stream's
        // flush splits the chunk sequence, so a sharded switch must
        // take (and now count) the serial fallback.
        let streams: Vec<Vec<KvPair>> = (0..2).map(|i| pairs(1_000, 100, 40 + i)).collect();
        let mut sharded = configured_switch(16 << 10, Some(256 << 10), 1);
        sharded.set_parallelism(crate::switch::parallel::Parallelism::Sharded(4));
        sharded.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
        assert!(
            sharded.stats(TreeId(1)).unwrap().fallback_serial > 0,
            "mid-stream flush must be recorded as a serial fallback"
        );

        // A clean end-of-stream flush stays on the sharded engine.
        let mut clean = configured_switch(16 << 10, Some(256 << 10), 2);
        clean.set_parallelism(crate::switch::parallel::Parallelism::Sharded(4));
        clean.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
        assert_eq!(clean.stats(TreeId(1)).unwrap().fallback_serial, 0);

        // The serial reference never counts fallbacks.
        let mut serial = configured_switch(16 << 10, Some(256 << 10), 1);
        serial.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
        assert_eq!(serial.stats(TreeId(1)).unwrap().fallback_serial, 0);
    }

    fn configured_vector_switch(
        fpe_mem: u64,
        bpe_mem: Option<u64>,
        children: u16,
        lanes: usize,
    ) -> SwitchAggSwitch {
        let cfg = SwitchConfig::scaled(fpe_mem, bpe_mem);
        let mut sw = SwitchAggSwitch::new(cfg);
        sw.configure_vector(
            &[TreeConfig {
                tree: TreeId(1),
                children,
                parent_port: 0,
                op: AggOp::Sum,
            }],
            lanes,
        );
        sw
    }

    fn vector_streams(
        n_streams: usize,
        n: usize,
        distinct: u64,
        lanes: usize,
        seed: u64,
    ) -> Vec<VectorBatch> {
        let mut rng = Pcg32::new(seed);
        (0..n_streams)
            .map(|_| {
                let mut b = VectorBatch::new(lanes);
                let mut vals: Vec<Value> = vec![0; lanes];
                for _ in 0..n {
                    let id = rng.gen_range_u64(distinct);
                    for (l, v) in vals.iter_mut().enumerate() {
                        *v = (id % 7) as i64 + l as i64 - 3;
                    }
                    b.push(Key::from_id(id, 16 + (id % 49) as usize), &vals);
                }
                b
            })
            .collect()
    }

    #[test]
    fn vector_w1_ingest_is_byte_identical_to_scalar() {
        // The degenerate 1-lane vector path against the scalar path on
        // the same stream: outputs, stats, and DRAM counters must all
        // be byte-identical.
        let input = pairs(8_000, 900, 55);
        let mut scalar = configured_switch(16 << 10, Some(256 << 10), 1);
        let out_scalar = scalar.ingest_stream(TreeId(1), AggOp::Sum, &input);

        let mut vector = configured_vector_switch(16 << 10, Some(256 << 10), 1, 1);
        let batch = VectorBatch::from_pairs(&input);
        let out_vector = vector.ingest_vector_stream(TreeId(1), &batch);

        assert_eq!(out_vector.to_pairs(), out_scalar);
        let a = scalar.stats(TreeId(1)).unwrap();
        let b = vector.stats(TreeId(1)).unwrap();
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        assert_eq!(
            scalar.bpe_dram_stats(TreeId(1)),
            vector.bpe_dram_stats(TreeId(1))
        );
        assert_eq!(
            scalar.avg_fpe_latency(TreeId(1)),
            vector.avg_fpe_latency(TreeId(1))
        );
    }

    #[test]
    fn vector_sum_is_conserved_lane_wise() {
        let lanes = 8;
        let streams = vector_streams(3, 2_000, 400, lanes, 77);
        let mut want = vec![0i64; lanes];
        for s in &streams {
            for (_, ls) in s.iter() {
                for (w, v) in want.iter_mut().zip(ls) {
                    *w += v;
                }
            }
        }
        let mut sw = configured_vector_switch(32 << 10, Some(1 << 20), 3, lanes);
        let out = sw.ingest_vector_child_streams(TreeId(1), &streams);
        let mut got = vec![0i64; lanes];
        for (_, ls) in out.iter() {
            for (g, v) in got.iter_mut().zip(ls) {
                *g += v;
            }
        }
        assert_eq!(got, want);
        let s = sw.stats(TreeId(1)).unwrap();
        assert_eq!(s.pairs_in, 6_000);
        assert!(s.reduction_ratio() > 0.0, "r={}", s.reduction_ratio());
    }

    #[test]
    fn vector_keys_fully_aggregated_when_memory_sufficient() {
        let lanes = 16;
        let streams = vector_streams(2, 3_000, 100, lanes, 9);
        let mut sw = configured_vector_switch(4 << 20, Some(8 << 20), 2, lanes);
        let out = sw.ingest_vector_child_streams(TreeId(1), &streams);
        let mut seen = std::collections::HashMap::new();
        for (k, _) in out.iter() {
            *seen.entry(*k).or_insert(0u32) += 1;
        }
        assert!(seen.values().all(|&c| c == 1), "duplicate keys in output");
        assert_eq!(seen.len(), 100);
        let s = sw.stats(TreeId(1)).unwrap();
        assert!(s.reduction_ratio() > 0.9, "r={}", s.reduction_ratio());
    }

    #[test]
    fn vector_sink_reuse_stops_allocating() {
        let lanes = 4;
        let streams = vector_streams(1, 1_500, 300, lanes, 13);
        let mut sw = configured_vector_switch(16 << 10, Some(256 << 10), 1, lanes);
        let mut sink = VectorSink::new(lanes);
        sw.ingest_vector_stream_into(TreeId(1), &streams[0], &mut sink);
        let warm = sink.capacity();
        for _ in 0..3 {
            sink.clear();
            sw.ingest_vector_stream_into(TreeId(1), &streams[0], &mut sink);
        }
        assert_eq!(sink.capacity(), warm, "steady-state vector ingest must not grow buffers");
    }

    #[test]
    #[should_panic(expected = "scalar ingest on a tree configured")]
    fn scalar_ingest_on_vector_tree_panics() {
        let mut sw = configured_vector_switch(16 << 10, None, 1, 8);
        sw.ingest_stream(TreeId(1), AggOp::Sum, &pairs(10, 5, 1));
    }

    #[test]
    #[should_panic(expected = "lane width does not match")]
    fn mismatched_lane_width_panics() {
        let mut sw = configured_vector_switch(16 << 10, None, 1, 8);
        let streams = vector_streams(1, 10, 5, 4, 1);
        sw.ingest_vector_stream(TreeId(1), &streams[0]);
    }

    /// Re-stamp a reliable stream's packets with a new epoch.
    fn restamp_epoch(pkts: &mut [AggregationPacket], epoch: u16) {
        for p in pkts.iter_mut() {
            p.rel.as_mut().unwrap().epoch = epoch;
        }
    }

    #[test]
    fn stale_epoch_retransmission_is_fenced_not_double_counted() {
        // Crash + restart mid-stream: the replay under the new epoch
        // must produce exactly the fault-free aggregate even while
        // old-incarnation retransmissions keep arriving.
        let tree = TreeId(1);
        let input = pairs(2_000, 400, 7);
        let want: Value = input.iter().map(|p| p.value).sum();
        let mut pkts = rel_packets(tree, 0, &input);

        let mut sw = configured_switch(16 << 10, Some(256 << 10), 1);
        let mut sink = IngestSink::new();
        // Epoch 0: half the stream lands, then the switch dies.
        let half = pkts.len() / 2;
        for p in &pkts[..half] {
            sw.ingest_reliable_one(tree, p, &mut sink);
        }
        sw.crash();
        assert_eq!(sw.n_trees(), 0, "crash loses all tree state");

        // Controller re-pushes Configure, then fences epoch 1.
        sw.configure(&[TreeConfig {
            tree,
            children: 1,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        sw.begin_epoch(tree, 1);
        assert_eq!(sw.tree_epoch(tree), 1);
        sink.clear();

        // A straggling epoch-0 retransmission arrives first: fenced —
        // no engine state, no dedup window, but the ack tells the
        // sender the current epoch.
        let ack = sw.ingest_reliable_one(tree, &pkts[0], &mut sink);
        assert_eq!(ack.epoch, 1);
        assert_eq!(ack.cum_seq, 0, "stale packet admitted nothing");
        assert_eq!(sw.dedup_stats(tree).stale_epoch_drops, 1);
        assert_eq!(sw.dedup_stats(tree).admitted, 0);

        // The rebased sender replays the whole stream under epoch 1,
        // with a stale duplicate interleaved mid-replay.
        restamp_epoch(&mut pkts, 1);
        for (i, p) in pkts.iter().enumerate() {
            sw.ingest_reliable_one(tree, p, &mut sink);
            if i == half {
                let mut stale = pkts[10].clone();
                stale.rel.as_mut().unwrap().epoch = 0;
                sw.ingest_reliable_one(tree, &stale, &mut sink);
            }
        }
        assert_eq!(sink.flushes, 1, "EoT fires once under the new epoch");
        let got: Value = sink_to_vec(&sink).iter().map(|p| p.value).sum();
        assert_eq!(got, want, "byte-identical to the fault-free aggregate");
        let d = sw.dedup_stats(tree);
        assert_eq!(d.stale_epoch_drops, 2, "both stale packets fenced");
        assert_eq!(d.admitted, pkts.len() as u64);
        assert_eq!(d.dup_drops, 0, "stale packets never reach a window");
    }

    #[test]
    #[should_panic(expected = "epoch must not regress")]
    fn epoch_regression_panics() {
        let mut sw = configured_switch(16 << 10, None, 1);
        sw.begin_epoch(TreeId(1), 3);
        sw.begin_epoch(TreeId(1), 2);
    }
}
