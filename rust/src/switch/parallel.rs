//! Parallel execution engine for the switch fabric: ingest sharded by
//! key-length group (= FPE) across a scoped-`std::thread` worker pool,
//! with a deterministic merge stage.
//!
//! # Why this is exact
//!
//! The per-pair pipeline factorizes by group: a pair's group is a pure
//! function of its key length, each FPE serves exactly one group, and
//! the BPE's memory is partitioned into per-group regions — so the
//! *functional* state touched by a pair lives entirely inside its
//! group.  The cross-group couplings are (a) the input pacing (a
//! global byte counter), (b) the shared BPE timing (FIFO/busy/DRAM),
//! and (c) the emission order of forwarded pairs.  The engine splits
//! along exactly those seams:
//!
//! 1. a serial **front end** walks the chunks in arrival order, doing
//!    the byte-pacing and payload-analyzer accounting and stamping
//!    every pair with its global sequence number and arrival cycle;
//! 2. **workers** own disjoint `{Fpe, BPE region, crossbar output}`
//!    shards and run the full per-pair hot path (hash, probe, evict,
//!    BPE probe) for their groups independently;
//! 3. a serial **merge** reorders worker outputs by sequence number,
//!    replays BPE arrivals through the shared timing model
//!    ([`crate::switch::bpe::Bpe::replay_timing`]), and emits
//!    forwarded pairs downstream in the serial path's order.
//!
//! Outputs *and* stats are byte-identical to the serial path (pinned
//! by `tests/parallel_determinism.rs`); the serial path remains the
//! correctness reference.

use crate::protocol::{AggOp, KvPair};
use crate::sim::Cycles;
use crate::switch::crossbar::PortView;
use crate::switch::fpe::{Fpe, FpeOutcome};
use crate::switch::hash_table::{HashTable, Probe};

/// How much of the fabric engine runs on worker threads.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Parallelism {
    /// Single-threaded reference path (the default).
    #[default]
    Serial,
    /// Ingest sharded by FPE group over this many workers; experiment
    /// sweeps fan scenario rows over the same pool.
    Sharded(usize),
}

impl Parallelism {
    /// Worker count (1 for the serial path).
    pub fn shards(&self) -> usize {
        match self {
            Parallelism::Serial => 1,
            Parallelism::Sharded(n) => (*n).max(1),
        }
    }

    /// Split the worker budget between an outer scenario fan-out of
    /// `outer_items` rows and each row's inner engine, so nested
    /// parallelism (sweep × sharded switch) cannot oversubscribe:
    /// `outer × inner.shards() <= self.shards()`.  Returns the outer
    /// worker count and the inner [`Parallelism`].
    pub fn split(&self, outer_items: usize) -> (usize, Parallelism) {
        let total = self.shards();
        let outer = total.min(outer_items.max(1));
        let inner = total / outer;
        let inner = if inner > 1 {
            Parallelism::Sharded(inner)
        } else {
            Parallelism::Serial
        };
        (outer, inner)
    }

    /// Parse `SWITCHAGG_PARALLEL`: unset/empty/`serial` → [`Self::Serial`],
    /// a number → [`Self::Sharded`] with that many workers.
    pub fn from_env() -> Self {
        match std::env::var("SWITCHAGG_PARALLEL") {
            Ok(v) => Self::parse(&v),
            Err(_) => Parallelism::Serial,
        }
    }

    /// Parse a config string (see [`Self::from_env`]).  Unparseable or
    /// zero values fall back to `Serial` *with a stderr warning*, so a
    /// typo'd `SWITCHAGG_PARALLEL` cannot silently record serial bench
    /// numbers as parallel ones.
    pub fn parse(s: &str) -> Self {
        let t = s.trim();
        if t.is_empty() || t.eq_ignore_ascii_case("serial") {
            return Parallelism::Serial;
        }
        match t.parse::<usize>() {
            Ok(n) if n >= 1 => Parallelism::Sharded(n),
            _ => {
                eprintln!(
                    "SWITCHAGG_PARALLEL={s:?} is not \"serial\" or a shard count >= 1; \
                     falling back to the serial engine"
                );
                Parallelism::Serial
            }
        }
    }
}

/// One pair as stamped by the front end: global sequence number (the
/// merge key) and arrival cycle at the FPE input stage.
#[derive(Clone, Copy, Debug)]
pub(crate) struct JobPair {
    pub seq: u64,
    pub arrive: Cycles,
    pub pair: KvPair,
}

/// Everything one worker needs to run one group's slice of the data
/// plane; the `&mut` borrows are disjoint across groups.
pub(crate) struct WorkerGroup<'a> {
    pub group: usize,
    pub job: Vec<JobPair>,
    pub fpe: &'a mut Fpe,
    /// This group's BPE region (`None` when the hierarchy is off).
    pub region: Option<&'a mut HashTable>,
    pub port: PortView,
    pub op: AggOp,
    /// BPE probe policy (`EvictionPolicy::EvictOld`).
    pub evict_old: bool,
}

/// One group's worker results, merged serially afterwards.
pub(crate) struct GroupOutput {
    pub group: usize,
    pub port: PortView,
    /// Pairs leaving the switch, tagged with the triggering pair's seq.
    pub emissions: Vec<(u64, KvPair)>,
    /// FPE→BPE evictions `(seq, (group, ready cycle))` for the
    /// scheduler-grant and shared-timing replay.
    pub evicts: Vec<(u64, (usize, Cycles))>,
    pub bpe_aggregated: u64,
    pub bpe_inserted: u64,
    pub bpe_overflowed: u64,
}

/// Run one group's pair stream through its FPE (and BPE region).
/// Functionally identical to the serial `TreeEngine::ingest_pairs`
/// inner loop restricted to this group.
pub(crate) fn run_shard_group(mut wg: WorkerGroup<'_>) -> GroupOutput {
    let mut emissions = Vec::new();
    let mut evicts = Vec::new();
    let (mut aggregated, mut inserted, mut overflowed) = (0u64, 0u64, 0u64);
    for jp in &wg.job {
        let deliver = wg.port.route(jp.arrive);
        match wg.fpe.offer(deliver, jp.pair.key, jp.pair.value, wg.op) {
            FpeOutcome::Kept => {}
            FpeOutcome::Forwarded {
                key,
                value,
                hash,
                ready,
            } => match wg.region.as_deref_mut() {
                Some(region) => {
                    evicts.push((jp.seq, (wg.group, ready)));
                    match region.offer_hashed(hash, key, value, wg.op, wg.evict_old) {
                        Probe::Aggregated => aggregated += 1,
                        Probe::Inserted => inserted += 1,
                        Probe::Evicted(k, v, _) => {
                            overflowed += 1;
                            emissions.push((jp.seq, KvPair::new(k, v)));
                        }
                    }
                }
                None => emissions.push((jp.seq, KvPair::new(key, value))),
            },
        }
    }
    GroupOutput {
        group: wg.group,
        port: wg.port,
        emissions,
        evicts,
        bpe_aggregated: aggregated,
        bpe_inserted: inserted,
        bpe_overflowed: overflowed,
    }
}

/// Run each worker's batch of groups on its own scoped thread and
/// collect the per-group outputs (any order; callers merge by seq).
pub(crate) fn run_workers(per_worker: Vec<Vec<WorkerGroup<'_>>>) -> Vec<GroupOutput> {
    // One live batch: no point paying a thread spawn.
    let live = per_worker.iter().filter(|b| !b.is_empty()).count();
    if live <= 1 {
        return per_worker
            .into_iter()
            .flatten()
            .map(run_shard_group)
            .collect();
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = per_worker
            .into_iter()
            .filter(|batch| !batch.is_empty())
            .map(|batch| {
                scope.spawn(move || {
                    batch
                        .into_iter()
                        .map(run_shard_group)
                        .collect::<Vec<GroupOutput>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("ingest shard worker panicked"))
            .collect()
    })
}

/// Merge per-group streams (each ascending in their `u64` key) into one
/// ascending stream.  Keys are globally unique (a pair has exactly one
/// group), so the order is total and deterministic.
pub(crate) fn merge_by_seq<T: Copy>(streams: &[&[(u64, T)]]) -> Vec<(u64, T)> {
    let total: usize = streams.iter().map(|s| s.len()).sum();
    let mut out = Vec::with_capacity(total);
    let mut idx = vec![0usize; streams.len()];
    loop {
        let mut best: Option<(usize, u64)> = None;
        for (i, s) in streams.iter().enumerate() {
            if let Some(&(k, _)) = s.get(idx[i]) {
                let wins = match best {
                    None => true,
                    Some((_, bk)) => k < bk,
                };
                if wins {
                    best = Some((i, k));
                }
            }
        }
        let Some((i, _)) = best else { break };
        out.push(streams[i][idx[i]]);
        idx[i] += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelism_parsing() {
        assert_eq!(Parallelism::parse(""), Parallelism::Serial);
        assert_eq!(Parallelism::parse("serial"), Parallelism::Serial);
        assert_eq!(Parallelism::parse("Serial"), Parallelism::Serial);
        assert_eq!(Parallelism::parse("4"), Parallelism::Sharded(4));
        assert_eq!(Parallelism::parse(" 8 "), Parallelism::Sharded(8));
        assert_eq!(Parallelism::parse("0"), Parallelism::Serial);
        assert_eq!(Parallelism::parse("bogus"), Parallelism::Serial);
        assert_eq!(Parallelism::Serial.shards(), 1);
        assert_eq!(Parallelism::Sharded(4).shards(), 4);
        assert_eq!(Parallelism::default(), Parallelism::Serial);
    }

    #[test]
    fn split_bounds_nested_thread_budget() {
        // outer × inner.shards() never exceeds the total budget.
        for total in 1..=16usize {
            for items in 1..=6usize {
                let (outer, inner) = Parallelism::Sharded(total).split(items);
                assert!(outer >= 1 && outer <= items.max(1));
                assert!(outer * inner.shards() <= total.max(1), "{total} {items}");
            }
        }
        assert_eq!(Parallelism::Serial.split(4), (1, Parallelism::Serial));
        assert_eq!(Parallelism::Sharded(8).split(4), (4, Parallelism::Sharded(2)));
        assert_eq!(Parallelism::Sharded(4).split(4), (4, Parallelism::Serial));
        assert_eq!(Parallelism::Sharded(8).split(1), (1, Parallelism::Sharded(8)));
    }

    #[test]
    fn merge_by_seq_interleaves_deterministically() {
        let a: Vec<(u64, char)> = vec![(0, 'a'), (3, 'a'), (4, 'a')];
        let b: Vec<(u64, char)> = vec![(1, 'b'), (5, 'b')];
        let c: Vec<(u64, char)> = vec![(2, 'c')];
        let merged = merge_by_seq(&[&a, &b, &c]);
        let seqs: Vec<u64> = merged.iter().map(|&(s, _)| s).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4, 5]);
        let tags: Vec<char> = merged.iter().map(|&(_, t)| t).collect();
        assert_eq!(tags, vec!['a', 'b', 'c', 'a', 'a', 'b']);
        assert!(merge_by_seq::<char>(&[]).is_empty());
        assert!(merge_by_seq::<char>(&[&[], &[]]).is_empty());
    }
}
