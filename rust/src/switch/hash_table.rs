//! Hash-table layout of the processing engines (Fig. 8).
//!
//! A contiguous memory region is divided into buckets; each bucket
//! holds `slots_per_bucket` slots of identical width (the group's
//! maximum key length, zero-padded — Fig. 8a).  A lookup compares the
//! key against the slots of its bucket; on a miss with a full bucket
//! the engine *evicts* a resident pair (the multi-level hierarchy
//! forwards it to the BPE / next hop instead of stalling, Fig. 7).
//!
//! Memory accounting matches the hardware: a slot costs
//! `slot_key_width + VALUE_BYTES` bytes, so a "4 MB BRAM" table holds
//! exactly as many pairs as the paper's would.
//!
//! # Layout
//!
//! Slots are stored struct-of-arrays: per bucket, a dense lane of
//! 32-bit *tags* (the cached FNV-1a hash of each resident key) is
//! scanned first, and the 64-byte key compare runs only on tag hits.
//! A bucket's tag lane is contiguous and at most `spb × 4` bytes, so
//! the common probe touches one cache line instead of walking ~80-byte
//! AoS entries.  The tag is the same `hash` value threaded through
//! [`HashTable::offer_hashed`] and [`Probe::Evicted`], so the FPE→BPE
//! handoff never rehashes.
//!
//! Dense and sparse tables share the SoA core: a *dense* table maps
//! bucket `b` to block `b` directly (FPE BRAM, index-addressed), while
//! a *sparse* table keeps a bucket-id → block map and appends blocks on
//! first touch, so a paper-scale 8 GB BPE region allocates memory
//! proportional to occupancy while its collision/eviction behaviour is
//! exactly that of the dense layout.
//!
//! Slots within a bucket fill a compact prefix (`len` per block): the
//! table has no per-key removal (only whole-table drain), so no holes
//! can form and probes scan exactly the occupied slots.
//!
//! # W-lane vector values
//!
//! A table may be built with a *lane width* `W` ≥ 1
//! ([`HashTable::with_memory_lanes`]): each slot then holds `W` values
//! in one flat, stride-`W` lane buffer alongside the tag/key/len
//! lanes, and an aggregate hit combines all `W` lanes in one
//! autovectorizable [`AggOp::combine_slice`] pass — one hash + one
//! probe amortized over `W` lane-combines, which is where multi-word
//! tensor aggregation (allreduce) earns its keep.  Scalar tables are
//! the degenerate `W = 1` case: same storage layout, same probe
//! sequence, same counters.  Slot memory accounting scales with the
//! lanes (`slot_key_width + W × VALUE_BYTES`), so a fixed-size BRAM
//! holds proportionally fewer wide slots.
//!
//! All combines — scalar, batched, and lane-wise — are counted at this
//! single point ([`HashTable::combines`], one count per lane-combine),
//! so engine op counters cannot drift from the combines that actually
//! ran.

use crate::protocol::vector::VectorBatch;
use crate::protocol::{AggOp, Key, KvPair, Value};
use crate::switch::hash::fnv1a_key;
use crate::util::codec::{self, SnapCursor, SnapshotError};
use crate::util::fxhash::FxHashMap;

/// On-wire/in-slot value width (the paper fixes values to 32 bits).
pub const VALUE_BYTES: usize = 4;

/// Outcome of offering a pair to a table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Probe {
    /// Key present — value aggregated in place.
    Aggregated,
    /// Key absent, free slot — pair stored.
    Inserted,
    /// Key absent, bucket full — a pair leaves the table.  Under
    /// `EvictOld` it is the resident pair (the incoming one took its
    /// slot); under `ForwardNew` it is the incoming pair itself.  The
    /// evictee's cached hash rides along so the next stage (BPE) need
    /// not recompute it.
    Evicted(Key, Value, u32),
}

/// What happened to a W-lane offer; the evictee (if any) was appended
/// — key, cached tag, and all `W` lanes — to the caller's
/// [`VectorEvictSink`], keeping the vector path allocation-free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneProbe {
    /// Key present — all `W` lanes combined in place.
    Aggregated,
    /// Key absent, free slot — lanes stored.
    Inserted,
    /// Key absent, bucket full — a W-lane pair left the table into the
    /// caller's sink (resident under `EvictOld`, incoming otherwise).
    Evicted,
}

/// Caller-owned, reusable buffer for W-lane evictees: keys ride with
/// their cached tag (the FPE→BPE handoff never rehashes) and lane data
/// stays columnar (flat, stride-`W`) — the eviction-path counterpart
/// of [`VectorBatch`].
#[derive(Clone, Debug, Default)]
pub struct VectorEvictSink {
    /// `(key, cached hash)` per evictee, in eviction order.
    pub keys: Vec<(Key, u32)>,
    /// Flat lane buffer; evictee `i` owns `lanes[i*W .. (i+1)*W]`.
    pub lanes: Vec<Value>,
}

impl VectorEvictSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn clear(&mut self) {
        self.keys.clear();
        self.lanes.clear();
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Evictee `i`'s lane slice for a width-`w` table.
    #[inline]
    pub fn lane_slice(&self, i: usize, w: usize) -> &[Value] {
        &self.lanes[i * w..(i + 1) * w]
    }
}

/// Struct-of-arrays slot storage over fixed-size blocks of
/// `spb` slots (one block per occupied bucket), with a stride-`lanes`
/// flat value buffer (`lanes == 1` is the scalar layout).
#[derive(Clone, Debug)]
struct SoaBlocks {
    spb: usize,
    /// Value lanes per slot (W); `vals` stride.
    lanes: usize,
    /// Cached hash (tag) per slot — the pre-filter lane.
    tags: Vec<u32>,
    keys: Vec<Key>,
    /// Flat lane buffer; slot `s` owns `vals[s*lanes .. (s+1)*lanes]`.
    vals: Vec<Value>,
    /// Occupied slots per block; slots `[0, len)` of a block are live.
    lens: Vec<u8>,
    /// Round-robin eviction cursor per block; always `< spb`.
    cursors: Vec<u8>,
}

impl SoaBlocks {
    fn with_blocks(spb: usize, lanes: usize, blocks: usize) -> Self {
        Self {
            spb,
            lanes,
            tags: vec![0; blocks * spb],
            keys: vec![Key::placeholder(); blocks * spb],
            vals: vec![0; blocks * spb * lanes],
            lens: vec![0; blocks],
            cursors: vec![0; blocks],
        }
    }

    /// Append an all-free block; returns its index.
    fn push_block(&mut self) -> usize {
        let blk = self.lens.len();
        self.tags.resize(self.tags.len() + self.spb, 0);
        self.keys.resize(self.keys.len() + self.spb, Key::placeholder());
        self.vals.resize(self.vals.len() + self.spb * self.lanes, 0);
        self.lens.push(0);
        self.cursors.push(0);
        blk
    }

    /// Drop every block but keep the allocations (sparse drain).
    fn clear(&mut self) {
        self.tags.clear();
        self.keys.clear();
        self.vals.clear();
        self.lens.clear();
        self.cursors.clear();
    }
}

/// Above this many slots the table stores only occupied buckets; the
/// FPE BRAM tables stay dense (fast, index-addressed) while a
/// paper-scale 8 GB BPE region does not allocate 8 GB.
const DENSE_SLOT_LIMIT: usize = 1 << 22;

/// How bucket indices map to SoA blocks.
#[derive(Clone, Debug)]
enum Mapping {
    /// Bucket `b` is block `b`; all blocks preallocated.
    Dense,
    /// bucket id → block index; blocks appended on first touch.
    Sparse(FxHashMap<u32, u32>),
}

/// One engine's hash table (one key-length group).
///
/// The *capacity* models the hardware memory (buckets × slots); the
/// *storage* is the SoA core above — dense for BRAM-sized tables,
/// occupancy-proportional for DRAM-sized ones.
#[derive(Clone, Debug)]
pub struct HashTable {
    slot_key_width: usize,
    slots_per_bucket: usize,
    buckets: usize,
    blocks: SoaBlocks,
    map: Mapping,
    occupancy: usize,
    pub lookups: u64,
    pub evictions: u64,
    /// Lane-combines executed by this table — the single accounting
    /// point for aggregation-ALU work (scalar hits count 1, W-lane
    /// hits count W), so engine op counters cannot drift from the
    /// combines that actually ran.
    pub combines: u64,
    /// Lane-combines whose result clamped at the value-range boundary
    /// (SUM saturation) — counted at the same single accounting point
    /// as `combines`, so no path can saturate silently.
    pub saturated: u64,
    /// Running audit digest: XOR over the *current* resident entries of
    /// a per-slot-lane signature ([`slot_sig`]).  Every legitimate
    /// mutation updates it incrementally (insert XORs the new sig in; a
    /// combine or evict-replace XORs the old sig out and the new one
    /// in; a drain zeroes it), so the digest telescopes to a pure
    /// function of current table state — order- and history-free, hence
    /// identical across the serial and sharded engines.  A memory fault
    /// ([`Self::poison_bit`]) bypasses it, which is exactly what
    /// [`Self::audit`] detects.
    audit_acc: u64,
}

/// Per-slot-lane audit signature.  An odd-constant multiply makes the
/// value injective into the pre-mix word and a splitmix64-style
/// finalizer (bijective) spreads it, so two entries differing in any of
/// (tag, lane, value) get distinct signatures and a single flipped
/// value bit always changes the table digest.
#[inline]
fn slot_sig(tag: u32, lane: usize, value: Value) -> u64 {
    let mut x = (value as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ ((tag as u64) << 1)
        ^ (lane as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x
}

impl HashTable {
    /// Build a table that fits `mem_bytes` of memory for keys padded to
    /// `slot_key_width`.  At least one bucket is always allocated.
    pub fn with_memory(mem_bytes: u64, slot_key_width: usize, slots_per_bucket: usize) -> Self {
        Self::with_memory_lanes(mem_bytes, slot_key_width, slots_per_bucket, 1)
    }

    /// [`Self::with_memory`] with `lanes` value lanes per slot: a slot
    /// costs `slot_key_width + lanes × VALUE_BYTES` bytes, so the same
    /// memory holds proportionally fewer wide slots.  `lanes == 1` is
    /// exactly the scalar table.
    pub fn with_memory_lanes(
        mem_bytes: u64,
        slot_key_width: usize,
        slots_per_bucket: usize,
        lanes: usize,
    ) -> Self {
        assert!(slot_key_width % 4 == 0 && slot_key_width > 0);
        assert!(slots_per_bucket > 0 && slots_per_bucket <= u8::MAX as usize);
        assert!(
            (1..=crate::protocol::MAX_LANES).contains(&lanes),
            "lane width {lanes} out of range"
        );
        let slot_bytes = (slot_key_width + lanes * VALUE_BYTES) as u64;
        let total_slots = (mem_bytes / slot_bytes).max(1) as usize;
        let buckets = (total_slots / slots_per_bucket).max(1);
        let (blocks, map) = if buckets * slots_per_bucket * lanes <= DENSE_SLOT_LIMIT {
            (
                SoaBlocks::with_blocks(slots_per_bucket, lanes, buckets),
                Mapping::Dense,
            )
        } else {
            (
                SoaBlocks::with_blocks(slots_per_bucket, lanes, 0),
                Mapping::Sparse(FxHashMap::default()),
            )
        };
        Self {
            slot_key_width,
            slots_per_bucket,
            buckets,
            blocks,
            map,
            occupancy: 0,
            lookups: 0,
            evictions: 0,
            combines: 0,
            saturated: 0,
            audit_acc: 0,
        }
    }

    pub fn slot_key_width(&self) -> usize {
        self.slot_key_width
    }

    /// Value lanes per slot (W); 1 for scalar tables.
    pub fn lanes(&self) -> usize {
        self.blocks.lanes
    }

    /// Bytes one slot occupies (padded key + all value lanes).
    pub fn slot_bytes(&self) -> usize {
        self.slot_key_width + self.blocks.lanes * VALUE_BYTES
    }

    pub fn capacity_pairs(&self) -> usize {
        self.buckets * self.slots_per_bucket
    }

    pub fn occupancy(&self) -> usize {
        self.occupancy
    }

    pub fn mem_bytes(&self) -> u64 {
        (self.capacity_pairs() * self.slot_bytes()) as u64
    }

    /// Hash a key for this table's slot width (cacheable by callers).
    #[inline]
    pub fn hash_of(&self, key: &Key) -> u32 {
        fnv1a_key(key, self.slot_key_width)
    }

    /// Block index for bucket `b`, materializing a sparse block on
    /// first touch.  Free function over the two fields so `offer_hashed`
    /// can keep disjoint borrows.
    #[inline]
    fn block_for(map: &mut Mapping, blocks: &mut SoaBlocks, b: usize) -> usize {
        match map {
            Mapping::Dense => b,
            Mapping::Sparse(m) => {
                if let Some(&blk) = m.get(&(b as u32)) {
                    blk as usize
                } else {
                    let blk = blocks.push_block();
                    m.insert(b as u32, blk as u32);
                    blk
                }
            }
        }
    }

    /// Read-only block lookup (`None` = bucket never touched).
    #[inline]
    fn block_for_read(&self, b: usize) -> Option<usize> {
        match &self.map {
            Mapping::Dense => Some(b),
            Mapping::Sparse(m) => m.get(&(b as u32)).map(|&blk| blk as usize),
        }
    }

    /// Offer a pair: aggregate, insert, or evict (Fig. 7).
    /// `evict_old`: true = paper behaviour (resident pair leaves).
    #[inline]
    pub fn offer(&mut self, key: Key, value: Value, op: AggOp, evict_old: bool) -> Probe {
        let hash = self.hash_of(&key);
        self.offer_hashed(hash, key, value, op, evict_old)
    }

    /// [`Self::offer`] with the key's hash precomputed (the FPE hash
    /// unit output travels with the pair to the BPE, Fig. 6).
    pub fn offer_hashed(
        &mut self,
        hash: u32,
        key: Key,
        value: Value,
        op: AggOp,
        evict_old: bool,
    ) -> Probe {
        debug_assert!(key.len() <= self.slot_key_width);
        debug_assert_eq!(hash, self.hash_of(&key));
        debug_assert_eq!(self.blocks.lanes, 1, "scalar offer on a W-lane table");
        self.lookups += 1;
        let b = (hash as usize) % self.buckets;
        let blk = Self::block_for(&mut self.map, &mut self.blocks, b);
        let spb = self.slots_per_bucket;
        let base = blk * spb;
        let len = self.blocks.lens[blk] as usize;

        // Tag pre-filter: scan the dense u32 lane; the wide key compare
        // runs only on tag hits (false positives are ~2^-32 per slot).
        for i in 0..len {
            if self.blocks.tags[base + i] == hash && self.blocks.keys[base + i] == key {
                let v = &mut self.blocks.vals[base + i];
                let old = *v;
                let (new, sat) = op.combine_observed(old, value);
                *v = new;
                self.combines += 1;
                self.saturated += sat as u64;
                self.audit_acc ^= slot_sig(hash, 0, old) ^ slot_sig(hash, 0, new);
                return Probe::Aggregated;
            }
        }
        if len < spb {
            self.blocks.tags[base + len] = hash;
            self.blocks.keys[base + len] = key;
            self.blocks.vals[base + len] = value;
            self.blocks.lens[blk] = (len + 1) as u8;
            self.occupancy += 1;
            self.audit_acc ^= slot_sig(hash, 0, value);
            return Probe::Inserted;
        }
        self.evictions += 1;
        if evict_old {
            let cur = self.blocks.cursors[blk] as usize;
            // Wrap at spb directly: a free-running u8 taken `% spb`
            // rotates victims unevenly whenever 256 % spb != 0.
            self.blocks.cursors[blk] = if cur + 1 >= spb { 0 } else { (cur + 1) as u8 };
            let vi = base + cur;
            let old_key = std::mem::replace(&mut self.blocks.keys[vi], key);
            let old_val = std::mem::replace(&mut self.blocks.vals[vi], value);
            let old_tag = std::mem::replace(&mut self.blocks.tags[vi], hash);
            self.audit_acc ^= slot_sig(old_tag, 0, old_val) ^ slot_sig(hash, 0, value);
            Probe::Evicted(old_key, old_val, old_tag)
        } else {
            Probe::Evicted(key, value, hash)
        }
    }

    /// Offer a batch of pairs (one packet's worth) in order, appending
    /// evictees — with their cached tag — to `evicted`; returns
    /// `(aggregated, inserted)` counts.  Two-phase per sub-batch: the
    /// hash unit runs as its own tight loop over the keys (no table
    /// traffic, so it pipelines), then the probe loop walks the table
    /// with every hash already in hand — the batched analogue of the
    /// FPE hash-unit/lookup split.  Outcomes are bit-identical to
    /// calling [`Self::offer`] per pair, and the caller-owned `evicted`
    /// buffer keeps the path allocation-free in steady state.
    pub fn offer_batch(
        &mut self,
        pairs: &[KvPair],
        op: AggOp,
        evict_old: bool,
        evicted: &mut Vec<(Key, Value, u32)>,
    ) -> (u64, u64) {
        const LANE: usize = 64;
        let mut hashes = [0u32; LANE];
        let mut aggregated = 0u64;
        let mut inserted = 0u64;
        for chunk in pairs.chunks(LANE) {
            for (h, p) in hashes.iter_mut().zip(chunk) {
                *h = self.hash_of(&p.key);
            }
            for (&hash, p) in hashes.iter().zip(chunk) {
                match self.offer_hashed(hash, p.key, p.value, op, evict_old) {
                    Probe::Aggregated => aggregated += 1,
                    Probe::Inserted => inserted += 1,
                    Probe::Evicted(k, v, h) => evicted.push((k, v, h)),
                }
            }
        }
        (aggregated, inserted)
    }

    /// Offer a W-lane pair: aggregate all lanes, insert, or evict.  The
    /// evictee (key + cached tag + lanes) is appended to the caller's
    /// sink, keeping the path allocation-free.  `lanes.len()` must
    /// equal the table's lane width; a 1-lane call is behaviourally
    /// identical to [`Self::offer`].
    #[inline]
    pub fn offer_lanes(
        &mut self,
        key: Key,
        lanes: &[Value],
        op: AggOp,
        evict_old: bool,
        evicted: &mut VectorEvictSink,
    ) -> LaneProbe {
        let hash = self.hash_of(&key);
        self.offer_lanes_hashed(hash, key, lanes, op, evict_old, evicted)
    }

    /// [`Self::offer_lanes`] with the key's hash precomputed.  The
    /// probe sequence (tag pre-filter, prefix fill, round-robin
    /// eviction cursor) is exactly [`Self::offer_hashed`]'s; only the
    /// value move widens from one ALU op to a stride-`W` slice combine.
    pub fn offer_lanes_hashed(
        &mut self,
        hash: u32,
        key: Key,
        lanes: &[Value],
        op: AggOp,
        evict_old: bool,
        evicted: &mut VectorEvictSink,
    ) -> LaneProbe {
        let w = self.blocks.lanes;
        debug_assert_eq!(lanes.len(), w, "lane width mismatch");
        debug_assert!(key.len() <= self.slot_key_width);
        debug_assert_eq!(hash, self.hash_of(&key));
        self.lookups += 1;
        let b = (hash as usize) % self.buckets;
        let blk = Self::block_for(&mut self.map, &mut self.blocks, b);
        let spb = self.slots_per_bucket;
        let base = blk * spb;
        let len = self.blocks.lens[blk] as usize;

        for i in 0..len {
            if self.blocks.tags[base + i] == hash && self.blocks.keys[base + i] == key {
                let vo = (base + i) * w;
                // Digest update brackets the combine: XOR the old lane
                // sigs out, combine (bit-identical to combine_slice),
                // XOR the new sigs in.
                for (l, &old) in self.blocks.vals[vo..vo + w].iter().enumerate() {
                    self.audit_acc ^= slot_sig(hash, l, old);
                }
                self.saturated += op.combine_slice_observed(&mut self.blocks.vals[vo..vo + w], lanes);
                for (l, &new) in self.blocks.vals[vo..vo + w].iter().enumerate() {
                    self.audit_acc ^= slot_sig(hash, l, new);
                }
                self.combines += w as u64;
                return LaneProbe::Aggregated;
            }
        }
        if len < spb {
            self.blocks.tags[base + len] = hash;
            self.blocks.keys[base + len] = key;
            let vo = (base + len) * w;
            self.blocks.vals[vo..vo + w].copy_from_slice(lanes);
            self.blocks.lens[blk] = (len + 1) as u8;
            self.occupancy += 1;
            for (l, &v) in lanes.iter().enumerate() {
                self.audit_acc ^= slot_sig(hash, l, v);
            }
            return LaneProbe::Inserted;
        }
        self.evictions += 1;
        if evict_old {
            let cur = self.blocks.cursors[blk] as usize;
            self.blocks.cursors[blk] = if cur + 1 >= spb { 0 } else { (cur + 1) as u8 };
            let vi = base + cur;
            let old_key = std::mem::replace(&mut self.blocks.keys[vi], key);
            let old_tag = std::mem::replace(&mut self.blocks.tags[vi], hash);
            let vo = vi * w;
            for (l, &old) in self.blocks.vals[vo..vo + w].iter().enumerate() {
                self.audit_acc ^= slot_sig(old_tag, l, old) ^ slot_sig(hash, l, lanes[l]);
            }
            evicted.keys.push((old_key, old_tag));
            evicted.lanes.extend_from_slice(&self.blocks.vals[vo..vo + w]);
            self.blocks.vals[vo..vo + w].copy_from_slice(lanes);
        } else {
            evicted.keys.push((key, hash));
            evicted.lanes.extend_from_slice(lanes);
        }
        LaneProbe::Evicted
    }

    /// Offer a whole columnar batch in order, appending evictees to
    /// `evicted`; returns `(aggregated, inserted)` counts.  Two-phase
    /// per sub-batch like [`Self::offer_batch`]: the hash unit runs as
    /// its own tight loop over the key column (the columnar layout is
    /// what makes that loop contiguous), then the probe loop walks the
    /// table with every hash in hand.  Outcomes are bit-identical to
    /// calling [`Self::offer_lanes`] per pair.
    pub fn offer_lanes_batch(
        &mut self,
        batch: &VectorBatch,
        op: AggOp,
        evict_old: bool,
        evicted: &mut VectorEvictSink,
    ) -> (u64, u64) {
        const LANE: usize = 64;
        let mut hashes = [0u32; LANE];
        let mut aggregated = 0u64;
        let mut inserted = 0u64;
        let n = batch.len();
        let mut pos = 0usize;
        while pos < n {
            let end = (pos + LANE).min(n);
            for (h, i) in hashes.iter_mut().zip(pos..end) {
                *h = self.hash_of(&batch.key(i));
            }
            for (&hash, i) in hashes.iter().zip(pos..end) {
                match self.offer_lanes_hashed(
                    hash,
                    batch.key(i),
                    batch.lane_slice(i),
                    op,
                    evict_old,
                    evicted,
                ) {
                    LaneProbe::Aggregated => aggregated += 1,
                    LaneProbe::Inserted => inserted += 1,
                    LaneProbe::Evicted => {}
                }
            }
            pos = end;
        }
        (aggregated, inserted)
    }

    /// Read a key's current value (tests / reducer verification).
    pub fn get(&self, key: &Key) -> Option<Value> {
        self.get_hashed(self.hash_of(key), key)
    }

    /// [`Self::get`] with the hash precomputed — the BPE/verification
    /// paths already hold the FPE hash-unit output, so the lookup need
    /// not rehash the key.
    pub fn get_hashed(&self, hash: u32, key: &Key) -> Option<Value> {
        debug_assert_eq!(hash, self.hash_of(key));
        debug_assert_eq!(self.blocks.lanes, 1, "scalar get on a W-lane table");
        let b = (hash as usize) % self.buckets;
        let blk = self.block_for_read(b)?;
        let base = blk * self.slots_per_bucket;
        let len = self.blocks.lens[blk] as usize;
        (0..len)
            .find(|&i| self.blocks.tags[base + i] == hash && self.blocks.keys[base + i] == *key)
            .map(|i| self.blocks.vals[base + i])
    }

    /// Read a key's current lane slice (tests / reducer verification).
    pub fn get_lanes(&self, key: &Key) -> Option<&[Value]> {
        let hash = self.hash_of(key);
        let w = self.blocks.lanes;
        let b = (hash as usize) % self.buckets;
        let blk = self.block_for_read(b)?;
        let base = blk * self.slots_per_bucket;
        let len = self.blocks.lens[blk] as usize;
        (0..len)
            .find(|&i| self.blocks.tags[base + i] == hash && self.blocks.keys[base + i] == *key)
            .map(|i| {
                let vo = (base + i) * w;
                &self.blocks.vals[vo..vo + w]
            })
    }

    /// Drain all resident pairs (flush to next hop / next stage) into
    /// `out`, in memory order (bucket index, then slot) — the BPE-Flush
    /// stage streams this out of RAM.  Appends without clearing so
    /// callers can reuse one scratch buffer across engines.
    pub fn drain_into(&mut self, out: &mut Vec<(Key, Value)>) {
        debug_assert_eq!(self.blocks.lanes, 1, "scalar drain on a W-lane table");
        let spb = self.slots_per_bucket;
        match &mut self.map {
            Mapping::Dense => {
                for blk in 0..self.blocks.lens.len() {
                    let len = self.blocks.lens[blk] as usize;
                    let base = blk * spb;
                    for i in 0..len {
                        out.push((self.blocks.keys[base + i], self.blocks.vals[base + i]));
                    }
                    self.blocks.lens[blk] = 0;
                    self.blocks.cursors[blk] = 0;
                }
            }
            Mapping::Sparse(m) => {
                let mut ids: Vec<(u32, u32)> = m.iter().map(|(&b, &blk)| (b, blk)).collect();
                ids.sort_unstable();
                for (_, blk) in ids {
                    let blk = blk as usize;
                    let len = self.blocks.lens[blk] as usize;
                    let base = blk * spb;
                    for i in 0..len {
                        out.push((self.blocks.keys[base + i], self.blocks.vals[base + i]));
                    }
                }
                m.clear();
                self.blocks.clear();
            }
        }
        self.occupancy = 0;
        self.audit_acc = 0;
    }

    /// [`Self::drain_into`] into a fresh vector.
    pub fn drain(&mut self) -> Vec<(Key, Value)> {
        let mut out = Vec::with_capacity(self.occupancy);
        self.drain_into(&mut out);
        out
    }

    /// Drain all resident W-lane pairs in memory order into columnar
    /// caller buffers (`out_keys[i]` owns
    /// `out_vals[i*W .. (i+1)*W]`) — the vector counterpart of
    /// [`Self::drain_into`], byte-identical to it at `W = 1` modulo the
    /// column split.  Appends without clearing so one scratch pair
    /// serves every engine.
    pub fn drain_lanes_into(&mut self, out_keys: &mut Vec<Key>, out_vals: &mut Vec<Value>) {
        let spb = self.slots_per_bucket;
        let w = self.blocks.lanes;
        match &mut self.map {
            Mapping::Dense => {
                for blk in 0..self.blocks.lens.len() {
                    let len = self.blocks.lens[blk] as usize;
                    let base = blk * spb;
                    out_keys.extend_from_slice(&self.blocks.keys[base..base + len]);
                    out_vals.extend_from_slice(&self.blocks.vals[base * w..(base + len) * w]);
                    self.blocks.lens[blk] = 0;
                    self.blocks.cursors[blk] = 0;
                }
            }
            Mapping::Sparse(m) => {
                let mut ids: Vec<(u32, u32)> = m.iter().map(|(&b, &blk)| (b, blk)).collect();
                ids.sort_unstable();
                for (_, blk) in ids {
                    let blk = blk as usize;
                    let len = self.blocks.lens[blk] as usize;
                    let base = blk * spb;
                    out_keys.extend_from_slice(&self.blocks.keys[base..base + len]);
                    out_vals.extend_from_slice(&self.blocks.vals[base * w..(base + len) * w]);
                }
                m.clear();
                self.blocks.clear();
            }
        }
        self.occupancy = 0;
        self.audit_acc = 0;
    }

    /// The running audit digest (0 for an empty table).
    pub fn audit_acc(&self) -> u64 {
        self.audit_acc
    }

    /// Recompute the audit digest from the resident slots and compare
    /// it against the incrementally-maintained one.  `Ok` means every
    /// resident bit is accounted for by legitimate mutations;
    /// `Err((expected, computed))` means memory was altered behind the
    /// engine's back (an SRAM upset / [`Self::poison_bit`]).
    pub fn audit(&self) -> Result<(), (u64, u64)> {
        let computed = self.recompute_audit();
        if computed == self.audit_acc {
            Ok(())
        } else {
            Err((self.audit_acc, computed))
        }
    }

    fn recompute_audit(&self) -> u64 {
        let spb = self.slots_per_bucket;
        let w = self.blocks.lanes;
        let mut acc = 0u64;
        for blk in 0..self.blocks.lens.len() {
            let len = self.blocks.lens[blk] as usize;
            let base = blk * spb;
            for i in 0..len {
                let tag = self.blocks.tags[base + i];
                let vo = (base + i) * w;
                for l in 0..w {
                    acc ^= slot_sig(tag, l, self.blocks.vals[vo + l]);
                }
            }
        }
        acc
    }

    /// Flip one seeded bit of one resident value *without* updating the
    /// audit digest — the SRAM single-event-upset model.  The seed
    /// picks the resident slot, lane, and bit.  Returns `false` (no
    /// fault landed) on an empty table.  Because [`slot_sig`] is
    /// value-injective per (tag, lane), a poisoned bit always makes
    /// [`Self::audit`] fail until the table is drained.
    pub fn poison_bit(&mut self, seed: u64) -> bool {
        if self.occupancy == 0 {
            return false;
        }
        let mut n = (seed % self.occupancy as u64) as usize;
        let spb = self.slots_per_bucket;
        let w = self.blocks.lanes;
        for blk in 0..self.blocks.lens.len() {
            let len = self.blocks.lens[blk] as usize;
            if n >= len {
                n -= len;
                continue;
            }
            let vo = (blk * spb + n) * w;
            let lane = ((seed >> 32) as usize) % w;
            let bit = ((seed >> 48) as usize) % 64;
            self.blocks.vals[vo + lane] ^= 1 << bit;
            return true;
        }
        false
    }

    /// Serialize the table's full functional state: geometry header,
    /// counters, audit digest, then each occupied bucket's live slot
    /// prefix in canonical memory order (dense: every block; sparse:
    /// bucket-id-sorted entries, re-insertable in order so block
    /// indices re-derive from insertion order).  Slots past a bucket's
    /// `len` are never serialized — they are never read before being
    /// overwritten, so the live prefix *is* the table state.
    pub(crate) fn snapshot_write(&self, out: &mut Vec<u8>) {
        codec::put_u32(out, self.slot_key_width as u32);
        codec::put_u32(out, self.slots_per_bucket as u32);
        codec::put_u64(out, self.buckets as u64);
        codec::put_u32(out, self.blocks.lanes as u32);
        codec::put_u64(out, self.occupancy as u64);
        codec::put_u64(out, self.lookups);
        codec::put_u64(out, self.evictions);
        codec::put_u64(out, self.combines);
        codec::put_u64(out, self.saturated);
        codec::put_u64(out, self.audit_acc);
        match &self.map {
            Mapping::Dense => {
                codec::put_u8(out, 0);
                for blk in 0..self.blocks.lens.len() {
                    Self::snapshot_write_block(out, &self.blocks, blk);
                }
            }
            Mapping::Sparse(m) => {
                codec::put_u8(out, 1);
                let mut ids: Vec<(u32, u32)> = m.iter().map(|(&b, &blk)| (b, blk)).collect();
                ids.sort_unstable();
                codec::put_u64(out, ids.len() as u64);
                for (b, blk) in ids {
                    codec::put_u32(out, b);
                    Self::snapshot_write_block(out, &self.blocks, blk as usize);
                }
            }
        }
    }

    fn snapshot_write_block(out: &mut Vec<u8>, blocks: &SoaBlocks, blk: usize) {
        let spb = blocks.spb;
        let w = blocks.lanes;
        let len = blocks.lens[blk] as usize;
        codec::put_u8(out, blocks.lens[blk]);
        codec::put_u8(out, blocks.cursors[blk]);
        let base = blk * spb;
        for i in 0..len {
            codec::put_u32(out, blocks.tags[base + i]);
            let k = &blocks.keys[base + i];
            codec::put_u8(out, k.len() as u8);
            out.extend_from_slice(k.as_bytes());
            for l in 0..w {
                codec::put_i64(out, blocks.vals[(base + i) * w + l]);
            }
        }
    }

    fn snapshot_read_block(
        cur: &mut SnapCursor<'_>,
        blocks: &mut SoaBlocks,
        blk: usize,
        width: usize,
    ) -> Result<usize, SnapshotError> {
        let spb = blocks.spb;
        let w = blocks.lanes;
        let len = cur.u8()? as usize;
        if len > spb {
            return Err(SnapshotError::Invalid("bucket len beyond slots_per_bucket"));
        }
        let cursor = cur.u8()?;
        if cursor as usize >= spb {
            return Err(SnapshotError::Invalid("eviction cursor beyond bucket"));
        }
        blocks.lens[blk] = len as u8;
        blocks.cursors[blk] = cursor;
        let base = blk * spb;
        for i in 0..len {
            let tag = cur.u32()?;
            let klen = cur.u8()? as usize;
            if klen > width {
                return Err(SnapshotError::Invalid("key longer than slot width"));
            }
            let key = Key::try_new(cur.bytes(klen)?)
                .ok_or(SnapshotError::Invalid("key length out of range"))?;
            blocks.tags[base + i] = tag;
            blocks.keys[base + i] = key;
            for l in 0..w {
                blocks.vals[(base + i) * w + l] = cur.i64()?;
            }
        }
        Ok(len)
    }

    /// Restore state serialized by [`Self::snapshot_write`] *in place*:
    /// the target must already have the identical geometry (the restore
    /// flow builds it from the same `TreeConfig` + memory shares), so
    /// no allocation-by-attacker is possible — dense storage is
    /// pre-sized and sparse blocks grow one bucket at a time, bounded
    /// by the bucket count.  Every length field is validated before
    /// use; malformed bytes yield a typed error, never a panic.
    pub(crate) fn snapshot_read_into(
        &mut self,
        cur: &mut SnapCursor<'_>,
    ) -> Result<(), SnapshotError> {
        if cur.u32()? as usize != self.slot_key_width {
            return Err(SnapshotError::Geometry("slot key width"));
        }
        if cur.u32()? as usize != self.slots_per_bucket {
            return Err(SnapshotError::Geometry("slots per bucket"));
        }
        if cur.u64()? != self.buckets as u64 {
            return Err(SnapshotError::Geometry("bucket count"));
        }
        if cur.u32()? as usize != self.blocks.lanes {
            return Err(SnapshotError::Geometry("lane width"));
        }
        let occupancy = cur.len()?;
        let lookups = cur.u64()?;
        let evictions = cur.u64()?;
        let combines = cur.u64()?;
        let saturated = cur.u64()?;
        let audit_acc = cur.u64()?;
        let kind = cur.u8()?;
        let width = self.slot_key_width;
        let mut live = 0usize;
        match (&mut self.map, kind) {
            (Mapping::Dense, 0) => {
                for blk in 0..self.blocks.lens.len() {
                    live += Self::snapshot_read_block(cur, &mut self.blocks, blk, width)?;
                }
            }
            (Mapping::Sparse(m), 1) => {
                let count = cur.len()?;
                if count > self.buckets {
                    return Err(SnapshotError::Invalid("more entries than buckets"));
                }
                m.clear();
                self.blocks.clear();
                let mut prev: Option<u32> = None;
                for _ in 0..count {
                    let b = cur.u32()?;
                    if b as u64 >= self.buckets as u64 {
                        return Err(SnapshotError::Invalid("bucket id out of range"));
                    }
                    if prev.is_some_and(|p| p >= b) {
                        return Err(SnapshotError::Invalid("bucket ids not strictly increasing"));
                    }
                    prev = Some(b);
                    let blk = self.blocks.push_block();
                    m.insert(b, blk as u32);
                    live += Self::snapshot_read_block(cur, &mut self.blocks, blk, width)?;
                }
            }
            _ => return Err(SnapshotError::Geometry("storage mapping")),
        }
        if live != occupancy {
            return Err(SnapshotError::Invalid("occupancy does not match live slots"));
        }
        self.occupancy = occupancy;
        self.lookups = lookups;
        self.evictions = evictions;
        self.combines = combines;
        self.saturated = saturated;
        // Restored verbatim, NOT recomputed: a table poisoned by an
        // SRAM flip before the snapshot must still fail `audit()` after
        // restore — the digest is state, not a checksum of the wire.
        self.audit_acc = audit_acc;
        Ok(())
    }

    /// Iterate resident pairs without draining (arbitrary order).
    pub fn iter(&self) -> impl Iterator<Item = (&Key, Value)> + '_ {
        debug_assert_eq!(self.blocks.lanes, 1, "scalar iter on a W-lane table");
        let spb = self.slots_per_bucket;
        let blocks = &self.blocks;
        blocks.lens.iter().enumerate().flat_map(move |(blk, &len)| {
            let base = blk * spb;
            blocks.keys[base..base + len as usize]
                .iter()
                .zip(blocks.vals[base..base + len as usize].iter().copied())
        })
    }

    /// Iterate resident W-lane pairs without draining (memory order).
    pub fn iter_lanes(&self) -> impl Iterator<Item = (&Key, &[Value])> + '_ {
        let spb = self.slots_per_bucket;
        let w = self.blocks.lanes;
        let blocks = &self.blocks;
        blocks.lens.iter().enumerate().flat_map(move |(blk, &len)| {
            let base = blk * spb;
            blocks.keys[base..base + len as usize]
                .iter()
                .zip(blocks.vals[base * w..(base + len as usize) * w].chunks_exact(w))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(pairs: usize, width: usize, spb: usize) -> HashTable {
        HashTable::with_memory((pairs * (width + VALUE_BYTES)) as u64, width, spb)
    }

    #[test]
    fn memory_accounting_matches_capacity() {
        let t = HashTable::with_memory(4 << 20, 16, 2);
        // 4 MiB / 20 B per slot = 209715 slots -> 104857 buckets * 2.
        assert_eq!(t.capacity_pairs(), 209_714);
        assert!(t.mem_bytes() <= 4 << 20);
    }

    #[test]
    fn aggregate_then_get() {
        let mut t = table(64, 16, 2);
        let k = Key::from_id(5, 12);
        assert_eq!(t.offer(k, 10, AggOp::Sum, true), Probe::Inserted);
        assert_eq!(t.offer(k, 32, AggOp::Sum, true), Probe::Aggregated);
        assert_eq!(t.get(&k), Some(42));
        assert_eq!(t.get_hashed(t.hash_of(&k), &k), Some(42));
        assert_eq!(t.occupancy(), 1);
    }

    #[test]
    fn eviction_old_vs_new() {
        // 1 bucket, 1 slot: second distinct key must evict.
        let mut t = table(1, 8, 1);
        let k1 = Key::from_id(1, 8);
        let k2 = Key::from_id(2, 8);
        assert_eq!(t.offer(k1, 11, AggOp::Sum, true), Probe::Inserted);
        match t.offer(k2, 22, AggOp::Sum, true) {
            Probe::Evicted(k, v, h) => {
                assert_eq!((k, v), (k1, 11)); // resident pair leaves
                assert_eq!(h, t.hash_of(&k1));
            }
            other => panic!("expected eviction, got {other:?}"),
        }
        assert_eq!(t.get(&k2), Some(22)); // newcomer resident

        let mut t = table(1, 8, 1);
        t.offer(k1, 11, AggOp::Sum, false);
        match t.offer(k2, 22, AggOp::Sum, false) {
            Probe::Evicted(k, v, _) => assert_eq!((k, v), (k2, 22)), // newcomer forwarded
            other => panic!("{other:?}"),
        }
        assert_eq!(t.get(&k1), Some(11));
    }

    #[test]
    fn bucket_scan_finds_second_slot() {
        // 24 bytes = exactly 2 slots of (8B key + 4B value) = 1 bucket.
        let mut t = HashTable::with_memory(24, 8, 2);
        assert_eq!(t.buckets, 1);
        let k1 = Key::from_id(1, 8);
        let k2 = Key::from_id(2, 8);
        assert_eq!(t.offer(k1, 1, AggOp::Sum, true), Probe::Inserted);
        assert_eq!(t.offer(k2, 2, AggOp::Sum, true), Probe::Inserted);
        assert_eq!(t.offer(k2, 3, AggOp::Sum, true), Probe::Aggregated);
        assert_eq!(t.get(&k2), Some(5));
        // Third key: round-robin eviction rotates victims.
        let k3 = Key::from_id(3, 8);
        let Probe::Evicted(v1, _, _) = t.offer(k3, 9, AggOp::Sum, true) else {
            panic!()
        };
        let k4 = Key::from_id(4, 8);
        let Probe::Evicted(v2, _, _) = t.offer(k4, 9, AggOp::Sum, true) else {
            panic!()
        };
        assert_ne!(v1, v2, "round-robin should rotate victims");
    }

    #[test]
    fn round_robin_eviction_unbiased_when_spb_not_power_of_two() {
        // spb = 3 does not divide 256: a free-running u8 cursor taken
        // `% 3` would double-serve slot 0 at every wrap.  With the
        // cursor wrapping at spb the full bucket behaves as a period-3
        // FIFO: the evictee is always the key offered 3 evictions ago.
        let mut t = HashTable::with_memory(3 * 12, 8, 3);
        assert_eq!(t.buckets, 1);
        let mut offered: Vec<Key> = Vec::new();
        for id in 0..3u64 {
            let k = Key::from_id(id, 8);
            assert_eq!(t.offer(k, 1, AggOp::Sum, true), Probe::Inserted);
            offered.push(k);
        }
        for id in 3..600u64 {
            let k = Key::from_id(id, 8);
            match t.offer(k, 1, AggOp::Sum, true) {
                Probe::Evicted(ek, _, _) => {
                    assert_eq!(
                        ek,
                        offered[offered.len() - 3],
                        "victim rotation broke at id {id}"
                    );
                }
                other => panic!("expected eviction, got {other:?}"),
            }
            offered.push(k);
        }
    }

    #[test]
    fn drain_returns_everything_once() {
        let mut t = table(128, 16, 2);
        let mut inserted = 0;
        for id in 0..80u64 {
            if matches!(
                t.offer(Key::from_id(id, 16), id as Value, AggOp::Sum, true),
                Probe::Inserted
            ) {
                inserted += 1;
            }
        }
        let drained = t.drain();
        assert_eq!(drained.len(), inserted);
        assert_eq!(t.occupancy(), 0);
        assert!(t.drain().is_empty());
    }

    #[test]
    fn drain_resets_eviction_cursors() {
        // After a drain the table must behave exactly like a fresh one.
        let mut t = HashTable::with_memory(24, 8, 2);
        for id in 0..7u64 {
            t.offer(Key::from_id(id, 8), 1, AggOp::Sum, true);
        }
        t.drain();
        let k1 = Key::from_id(100, 8);
        let k2 = Key::from_id(101, 8);
        let k3 = Key::from_id(102, 8);
        t.offer(k1, 1, AggOp::Sum, true);
        t.offer(k2, 1, AggOp::Sum, true);
        match t.offer(k3, 1, AggOp::Sum, true) {
            Probe::Evicted(ek, _, _) => assert_eq!(ek, k1, "cursor must restart at slot 0"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn offer_batch_matches_scalar_path() {
        let pairs: Vec<KvPair> = (0..500u64)
            .map(|id| KvPair::new(Key::from_id(id % 97, 16), (id % 13) as Value))
            .collect();
        let mut scalar = table(32, 16, 2);
        let mut scalar_evicted: Vec<(Key, Value, u32)> = Vec::new();
        let (mut agg_s, mut ins_s) = (0u64, 0u64);
        for p in &pairs {
            match scalar.offer(p.key, p.value, AggOp::Sum, true) {
                Probe::Aggregated => agg_s += 1,
                Probe::Inserted => ins_s += 1,
                Probe::Evicted(k, v, h) => scalar_evicted.push((k, v, h)),
            }
        }
        let mut batched = table(32, 16, 2);
        let mut batch_evicted: Vec<(Key, Value, u32)> = Vec::new();
        let (agg_b, ins_b) = batched.offer_batch(&pairs, AggOp::Sum, true, &mut batch_evicted);
        assert_eq!((agg_s, ins_s), (agg_b, ins_b));
        assert_eq!(scalar_evicted, batch_evicted);
        let a: Vec<(Key, Value)> = scalar.drain();
        let b: Vec<(Key, Value)> = batched.drain();
        assert_eq!(a, b);
    }

    #[test]
    fn value_conservation_under_sum() {
        // sum(inputs) == sum(resident) + sum(evicted) — the invariant
        // that makes in-network SUM correct end-to-end.
        let mut t = table(32, 16, 2);
        let mut evicted_sum: Value = 0;
        let mut input_sum: Value = 0;
        for id in 0..500u64 {
            let v = (id % 13) as Value;
            input_sum += v;
            if let Probe::Evicted(_, ev, _) = t.offer(Key::from_id(id % 97, 16), v, AggOp::Sum, true)
            {
                evicted_sum += ev;
            }
        }
        let resident_sum: Value = t.iter().map(|(_, v)| v).sum();
        assert_eq!(input_sum, resident_sum + evicted_sum);
    }

    #[test]
    fn sparse_table_allocates_proportional_to_occupancy() {
        // 1 GB worth of capacity must not allocate 1 GB of slots.
        let mut t = HashTable::with_memory(1 << 30, 64, 4);
        assert!(t.capacity_pairs() > DENSE_SLOT_LIMIT);
        assert!(matches!(t.map, Mapping::Sparse(_)));
        for id in 0..1000u64 {
            t.offer(Key::from_id(id, 64), 1, AggOp::Sum, true);
        }
        assert_eq!(t.occupancy(), 1000);
        // At most one block (spb slots) per offered key.
        assert!(t.blocks.lens.len() <= 1000);
        let drained = t.drain();
        assert_eq!(drained.len(), 1000);
        assert!(t.blocks.lens.is_empty(), "sparse drain releases blocks");
    }

    #[test]
    fn evicted_tag_matches_recomputed_hash() {
        let mut t = table(1, 16, 1);
        let k1 = Key::from_id(1, 16);
        let k2 = Key::from_id(2, 16);
        t.offer(k1, 1, AggOp::Sum, true);
        let Probe::Evicted(ek, _, tag) = t.offer(k2, 2, AggOp::Sum, true) else {
            panic!()
        };
        assert_eq!(tag, t.hash_of(&ek));
    }

    fn vtable(pairs: usize, width: usize, spb: usize, lanes: usize) -> HashTable {
        HashTable::with_memory_lanes(
            (pairs * (width + lanes * VALUE_BYTES)) as u64,
            width,
            spb,
            lanes,
        )
    }

    #[test]
    fn lane_memory_accounting_scales_capacity() {
        // Same bytes, 8 lanes: a slot is 16+32 B instead of 16+4 B.
        let scalar = HashTable::with_memory(4 << 20, 16, 2);
        let wide = HashTable::with_memory_lanes(4 << 20, 16, 2, 8);
        assert_eq!(wide.lanes(), 8);
        assert_eq!(wide.slot_bytes(), 16 + 8 * VALUE_BYTES);
        assert_eq!(scalar.lanes(), 1);
        assert_eq!(scalar.slot_bytes(), 20);
        assert!(wide.capacity_pairs() < scalar.capacity_pairs() / 2);
        assert!(wide.mem_bytes() <= 4 << 20);
    }

    #[test]
    fn w1_lane_path_matches_scalar_path_exactly() {
        // Same offers through offer() and offer_lanes() at W = 1:
        // identical outcomes, drained state, and counters.
        let pairs: Vec<KvPair> = (0..700u64)
            .map(|id| KvPair::new(Key::from_id(id % 83, 16), (id % 11) as Value - 5))
            .collect();
        for evict_old in [true, false] {
            let mut scalar = table(32, 16, 2);
            let mut svec: Vec<(Key, Value, u32)> = Vec::new();
            for p in &pairs {
                if let Probe::Evicted(k, v, h) = scalar.offer(p.key, p.value, AggOp::Sum, evict_old)
                {
                    svec.push((k, v, h));
                }
            }
            let mut lane = table(32, 16, 2);
            let mut sink = VectorEvictSink::new();
            for p in &pairs {
                lane.offer_lanes(
                    p.key,
                    std::slice::from_ref(&p.value),
                    AggOp::Sum,
                    evict_old,
                    &mut sink,
                );
            }
            let lvec: Vec<(Key, Value, u32)> = sink
                .keys
                .iter()
                .zip(&sink.lanes)
                .map(|(&(k, h), &v)| (k, v, h))
                .collect();
            assert_eq!(svec, lvec, "evict_old={evict_old}");
            assert_eq!(scalar.drain(), lane.drain());
            assert_eq!(scalar.lookups, lane.lookups);
            assert_eq!(scalar.evictions, lane.evictions);
            assert_eq!(scalar.combines, lane.combines);
        }
    }

    #[test]
    fn wide_aggregate_combines_every_lane() {
        let mut t = vtable(64, 16, 2, 8);
        let k = Key::from_id(5, 12);
        let a: Vec<Value> = (0..8).collect();
        let b: Vec<Value> = (0..8).map(|i| i * 10).collect();
        let mut sink = VectorEvictSink::new();
        assert_eq!(
            t.offer_lanes(k, &a, AggOp::Sum, true, &mut sink),
            LaneProbe::Inserted
        );
        assert_eq!(
            t.offer_lanes(k, &b, AggOp::Sum, true, &mut sink),
            LaneProbe::Aggregated
        );
        let want: Vec<Value> = (0..8).map(|i| i + i * 10).collect();
        assert_eq!(t.get_lanes(&k), Some(want.as_slice()));
        assert!(sink.is_empty());
        assert_eq!(t.combines, 8);
    }

    #[test]
    fn wide_eviction_carries_all_lanes_and_tag() {
        let mut t = vtable(1, 16, 1, 4);
        let k1 = Key::from_id(1, 16);
        let k2 = Key::from_id(2, 16);
        let mut sink = VectorEvictSink::new();
        t.offer_lanes(k1, &[1, 2, 3, 4], AggOp::Sum, true, &mut sink);
        assert_eq!(
            t.offer_lanes(k2, &[9, 9, 9, 9], AggOp::Sum, true, &mut sink),
            LaneProbe::Evicted
        );
        assert_eq!(sink.len(), 1);
        let (ek, tag) = sink.keys[0];
        assert_eq!(ek, k1);
        assert_eq!(tag, t.hash_of(&k1));
        assert_eq!(sink.lane_slice(0, 4), &[1, 2, 3, 4]);
        assert_eq!(t.get_lanes(&k2), Some([9i64, 9, 9, 9].as_slice()));

        // ForwardNew: the incoming pair leaves instead.
        let mut t = vtable(1, 16, 1, 4);
        let mut sink = VectorEvictSink::new();
        t.offer_lanes(k1, &[1, 2, 3, 4], AggOp::Sum, false, &mut sink);
        t.offer_lanes(k2, &[9, 8, 7, 6], AggOp::Sum, false, &mut sink);
        assert_eq!(sink.keys[0].0, k2);
        assert_eq!(sink.lane_slice(0, 4), &[9, 8, 7, 6]);
        assert_eq!(t.get_lanes(&k1), Some([1i64, 2, 3, 4].as_slice()));
    }

    #[test]
    fn lane_batch_matches_per_pair_offers() {
        let w = 16;
        let mut batch = VectorBatch::new(w);
        let mut lanes: Vec<Value> = vec![0; w];
        for id in 0..400u64 {
            for (l, v) in lanes.iter_mut().enumerate() {
                *v = (id % 13) as i64 + l as i64;
            }
            batch.push(Key::from_id(id % 37, 16), &lanes);
        }
        let mut one = vtable(16, 16, 2, w);
        let mut one_sink = VectorEvictSink::new();
        let (mut agg1, mut ins1) = (0u64, 0u64);
        for i in 0..batch.len() {
            match one.offer_lanes(batch.key(i), batch.lane_slice(i), AggOp::Sum, true, &mut one_sink)
            {
                LaneProbe::Aggregated => agg1 += 1,
                LaneProbe::Inserted => ins1 += 1,
                LaneProbe::Evicted => {}
            }
        }
        let mut batched = vtable(16, 16, 2, w);
        let mut batch_sink = VectorEvictSink::new();
        let (agg2, ins2) = batched.offer_lanes_batch(&batch, AggOp::Sum, true, &mut batch_sink);
        assert_eq!((agg1, ins1), (agg2, ins2));
        assert_eq!(one_sink.keys, batch_sink.keys);
        assert_eq!(one_sink.lanes, batch_sink.lanes);
        assert_eq!(one.combines, batched.combines);
        let mut k1 = Vec::new();
        let mut v1 = Vec::new();
        one.drain_lanes_into(&mut k1, &mut v1);
        let mut k2 = Vec::new();
        let mut v2 = Vec::new();
        batched.drain_lanes_into(&mut k2, &mut v2);
        assert_eq!((k1, v1), (k2, v2));
    }

    #[test]
    fn lane_value_conservation_under_sum() {
        // Per-lane conservation: sum(inputs) == sum(resident) +
        // sum(evicted), lane by lane.
        let w = 4;
        let mut t = vtable(32, 16, 2, w);
        let mut sink = VectorEvictSink::new();
        let mut input_sums = vec![0i64; w];
        for id in 0..500u64 {
            let lanes: Vec<Value> = (0..w as i64).map(|l| (id % 13) as i64 * (l + 1)).collect();
            for (s, v) in input_sums.iter_mut().zip(&lanes) {
                *s += v;
            }
            t.offer_lanes(Key::from_id(id % 97, 16), &lanes, AggOp::Sum, true, &mut sink);
        }
        let mut totals = vec![0i64; w];
        for (_, lanes) in t.iter_lanes() {
            for (s, v) in totals.iter_mut().zip(lanes) {
                *s += v;
            }
        }
        for i in 0..sink.len() {
            for (s, v) in totals.iter_mut().zip(sink.lane_slice(i, w)) {
                *s += v;
            }
        }
        assert_eq!(totals, input_sums);
    }

    #[test]
    fn combines_counter_is_the_single_accounting_point() {
        // ISSUE 3 satellite: scalar offers, batched offers, and the
        // W=1 lane path must report identical combine counts, equal to
        // the aggregated-hit count — no path bypasses the counter.
        let pairs: Vec<KvPair> = (0..600u64)
            .map(|id| KvPair::new(Key::from_id(id % 53, 16), 1))
            .collect();
        let mut scalar = table(64, 16, 2);
        let mut hits = 0u64;
        for p in &pairs {
            if scalar.offer(p.key, p.value, AggOp::Sum, true) == Probe::Aggregated {
                hits += 1;
            }
        }
        assert!(hits > 0);
        assert_eq!(scalar.combines, hits);

        let mut batched = table(64, 16, 2);
        let mut evicted: Vec<(Key, Value, u32)> = Vec::new();
        let (agg, _) = batched.offer_batch(&pairs, AggOp::Sum, true, &mut evicted);
        assert_eq!(batched.combines, agg);
        assert_eq!(batched.combines, scalar.combines);

        // W lanes: combines scale by exactly W per aggregated hit.
        let w = 8;
        let mut wide = vtable(64, 16, 2, w);
        let mut sink = VectorEvictSink::new();
        let lanes: Vec<Value> = vec![1; w];
        let mut whits = 0u64;
        for p in &pairs {
            if wide.offer_lanes(p.key, &lanes, AggOp::Sum, true, &mut sink)
                == LaneProbe::Aggregated
            {
                whits += 1;
            }
        }
        assert_eq!(whits, hits);
        assert_eq!(wide.combines, hits * w as u64);
    }

    #[test]
    fn audit_digest_holds_under_mixed_traffic_and_telescopes() {
        // Combines, inserts, evictions (both polarities), and drains
        // must all keep the incremental digest equal to a fresh
        // recompute — and equal between two tables that reach the same
        // state along different histories.
        let mut t = table(8, 16, 2);
        for id in 0..300u64 {
            t.offer(Key::from_id(id % 23, 16), (id % 7) as Value - 3, AggOp::Sum, id % 3 != 0);
            if id % 50 == 49 {
                t.audit().unwrap();
            }
        }
        t.audit().unwrap();
        t.drain();
        assert_eq!(t.audit_acc(), 0, "drain zeroes the digest");
        t.audit().unwrap();

        // History-free: insert a+b vs one combined offer of (a+b).
        let k = Key::from_id(7, 16);
        let mut two_steps = table(8, 16, 2);
        two_steps.offer(k, 30, AggOp::Sum, true);
        two_steps.offer(k, 12, AggOp::Sum, true);
        let mut one_step = table(8, 16, 2);
        one_step.offer(k, 42, AggOp::Sum, true);
        assert_eq!(two_steps.audit_acc(), one_step.audit_acc());

        // Lane path too.
        let mut v = vtable(8, 16, 2, 4);
        let mut sink = VectorEvictSink::new();
        for id in 0..300u64 {
            let lanes: Vec<Value> = (0..4).map(|l| (id % 9) as i64 - l).collect();
            v.offer_lanes(Key::from_id(id % 19, 16), &lanes, AggOp::Max, true, &mut sink);
        }
        v.audit().unwrap();
        let mut keys = Vec::new();
        let mut vals = Vec::new();
        v.drain_lanes_into(&mut keys, &mut vals);
        assert_eq!(v.audit_acc(), 0);
    }

    #[test]
    fn poisoned_bit_fails_audit_until_drain() {
        let mut t = table(32, 16, 2);
        for id in 0..40u64 {
            t.offer(Key::from_id(id, 16), id as Value, AggOp::Sum, true);
        }
        t.audit().unwrap();
        assert!(t.poison_bit(0x1234_5678_9ABC_DEF0));
        let (expected, computed) = t.audit().unwrap_err();
        assert_ne!(expected, computed);
        t.drain();
        t.audit().unwrap();
        // An empty table has nothing to poison.
        assert!(!t.poison_bit(1));

        // W-lane tables poison a single lane of a single slot.
        let mut v = vtable(32, 16, 2, 8);
        let mut sink = VectorEvictSink::new();
        for id in 0..20u64 {
            v.offer_lanes(Key::from_id(id, 16), &[1; 8], AggOp::Sum, true, &mut sink);
        }
        v.audit().unwrap();
        assert!(v.poison_bit(0xFEED_FACE_CAFE_BEEF));
        assert!(v.audit().is_err());
    }

    #[test]
    fn saturated_counter_tracks_clamped_combines() {
        let mut t = table(8, 16, 2);
        let k = Key::from_id(1, 16);
        t.offer(k, Value::MAX - 5, AggOp::Sum, true);
        assert_eq!(t.saturated, 0);
        t.offer(k, 3, AggOp::Sum, true);
        assert_eq!(t.saturated, 0, "headroom left: no clamp");
        t.offer(k, 100, AggOp::Sum, true);
        assert_eq!(t.saturated, 1);
        assert_eq!(t.get(&k), Some(Value::MAX), "value saturates like combine()");
        t.offer(k, 1, AggOp::Sum, true);
        assert_eq!(t.saturated, 2, "stuck at the rail keeps counting");
        t.audit().unwrap();

        // MAX/MIN never saturate; lane path counts per clamped lane.
        let mut v = vtable(8, 16, 2, 4);
        let mut sink = VectorEvictSink::new();
        let kv = Key::from_id(2, 16);
        v.offer_lanes(kv, &[Value::MAX, 0, Value::MIN, 5], AggOp::Sum, true, &mut sink);
        v.offer_lanes(kv, &[1, 1, -1, 1], AggOp::Sum, true, &mut sink);
        assert_eq!(v.saturated, 2, "two of four lanes clamped");
        v.audit().unwrap();
        let mut m = table(8, 16, 2);
        let km = Key::from_id(3, 16);
        m.offer(km, Value::MAX, AggOp::Max, true);
        m.offer(km, Value::MIN, AggOp::Max, true);
        assert_eq!(m.saturated, 0);
    }

    #[test]
    fn snapshot_roundtrip_continues_byte_identically() {
        // Ingest a prefix, snapshot, restore into a fresh same-geometry
        // table, then drive both through the same suffix: outcomes,
        // drained state, counters and digest must all match.
        let mut a = table(32, 16, 2);
        for id in 0..300u64 {
            a.offer(Key::from_id(id % 53, 16), (id % 11) as Value - 5, AggOp::Sum, true);
        }
        let mut bytes = Vec::new();
        a.snapshot_write(&mut bytes);
        let mut b = table(32, 16, 2);
        let mut cur = SnapCursor::new(&bytes);
        b.snapshot_read_into(&mut cur).unwrap();
        cur.finish().unwrap();
        assert_eq!(a.occupancy(), b.occupancy());
        assert_eq!(a.audit_acc(), b.audit_acc());
        b.audit().unwrap();
        for id in 300..600u64 {
            let k = Key::from_id(id % 53, 16);
            let v = (id % 11) as Value - 5;
            assert_eq!(
                a.offer(k, v, AggOp::Sum, true),
                b.offer(k, v, AggOp::Sum, true),
                "post-restore outcome diverged at id {id}"
            );
        }
        assert_eq!(
            (a.lookups, a.evictions, a.combines, a.saturated),
            (b.lookups, b.evictions, b.combines, b.saturated)
        );
        assert_eq!(a.drain(), b.drain());
    }

    #[test]
    fn snapshot_roundtrip_sparse_wide() {
        let mut a = HashTable::with_memory_lanes(1 << 30, 64, 4, 8);
        assert!(matches!(a.map, Mapping::Sparse(_)));
        let mut sink = VectorEvictSink::new();
        for id in 0..400u64 {
            let lanes: Vec<Value> = (0..8).map(|l| (id % 13) as i64 - l).collect();
            a.offer_lanes(Key::from_id(id, 64), &lanes, AggOp::Sum, true, &mut sink);
        }
        let mut bytes = Vec::new();
        a.snapshot_write(&mut bytes);
        let mut b = HashTable::with_memory_lanes(1 << 30, 64, 4, 8);
        b.snapshot_read_into(&mut SnapCursor::new(&bytes)).unwrap();
        b.audit().unwrap();
        for id in 0..400u64 {
            let k = Key::from_id(id, 64);
            assert_eq!(a.get_lanes(&k), b.get_lanes(&k));
        }
        let (mut ka, mut va, mut kb, mut vb) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        a.drain_lanes_into(&mut ka, &mut va);
        b.drain_lanes_into(&mut kb, &mut vb);
        assert_eq!((ka, va), (kb, vb));
    }

    #[test]
    fn snapshot_geometry_mismatch_is_typed() {
        let mut a = table(32, 16, 2);
        a.offer(Key::from_id(1, 16), 1, AggOp::Sum, true);
        let mut bytes = Vec::new();
        a.snapshot_write(&mut bytes);
        let mut wrong = table(32, 24, 2);
        assert!(matches!(
            wrong.snapshot_read_into(&mut SnapCursor::new(&bytes)),
            Err(SnapshotError::Geometry(_))
        ));
    }

    #[test]
    fn snapshot_decode_survives_truncation_and_flips() {
        let mut a = table(8, 16, 2);
        for id in 0..60u64 {
            a.offer(Key::from_id(id % 23, 16), id as Value, AggOp::Sum, true);
        }
        let mut bytes = Vec::new();
        a.snapshot_write(&mut bytes);
        for cut in 0..bytes.len() {
            let mut b = table(8, 16, 2);
            let mut cur = SnapCursor::new(&bytes[..cut]);
            let _ = b.snapshot_read_into(&mut cur); // must not panic
        }
        for i in (0..bytes.len()).step_by(7) {
            let mut flipped = bytes.clone();
            flipped[i] ^= 0x80;
            let mut b = table(8, 16, 2);
            let mut cur = SnapCursor::new(&flipped);
            let _ = b.snapshot_read_into(&mut cur); // must not panic
        }
    }

    #[test]
    fn sparse_wide_table_drains_columnar() {
        // A paper-scale wide region stays occupancy-proportional and
        // its columnar drain returns every lane once.
        let mut t = HashTable::with_memory_lanes(1 << 30, 64, 4, 64);
        assert!(matches!(t.map, Mapping::Sparse(_)));
        let lanes: Vec<Value> = (0..64).collect();
        let mut sink = VectorEvictSink::new();
        for id in 0..500u64 {
            t.offer_lanes(Key::from_id(id, 64), &lanes, AggOp::Sum, true, &mut sink);
        }
        assert_eq!(t.occupancy(), 500);
        let mut keys = Vec::new();
        let mut vals = Vec::new();
        t.drain_lanes_into(&mut keys, &mut vals);
        assert_eq!(keys.len(), 500);
        assert_eq!(vals.len(), 500 * 64);
        assert!(t.blocks.lens.is_empty(), "sparse drain releases blocks");
    }
}
