//! Hash-table layout of the processing engines (Fig. 8).
//!
//! A contiguous memory region is divided into buckets; each bucket
//! holds `slots_per_bucket` slots of identical width (the group's
//! maximum key length, zero-padded — Fig. 8a).  A lookup compares the
//! key against every slot of its bucket; on a miss with a full bucket
//! the engine *evicts* a resident pair (the multi-level hierarchy
//! forwards it to the BPE / next hop instead of stalling, Fig. 7).
//!
//! Memory accounting matches the hardware: a slot costs
//! `slot_key_width + VALUE_BYTES` bytes, so a "4 MB BRAM" table holds
//! exactly as many pairs as the paper's would.

use crate::protocol::{AggOp, Key, Value};
use crate::switch::hash::fnv1a_key;
use crate::util::fxhash::FxHashMap;

/// On-wire/in-slot value width (the paper fixes values to 32 bits).
pub const VALUE_BYTES: usize = 4;

/// Outcome of offering a pair to a table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Probe {
    /// Key present — value aggregated in place.
    Aggregated,
    /// Key absent, free slot — pair stored.
    Inserted,
    /// Key absent, bucket full — a pair leaves the table.  Under
    /// `EvictOld` it is the resident pair (the incoming one took its
    /// slot); under `ForwardNew` it is the incoming pair itself.  The
    /// evictee's cached hash rides along so the next stage (BPE) need
    /// not recompute it.
    Evicted(Key, Value, u32),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Slot {
    key: Key,
    value: Value,
    /// Cached fnv1a_key(key, slot_key_width) — simulator-side
    /// optimization; the hardware recomputes in its hash unit.
    hash: u32,
}

/// One bucket's occupied slots + its round-robin eviction cursor.
#[derive(Clone, Debug, Default)]
struct Bucket {
    slots: Vec<Slot>,
    cursor: u8,
}

/// Above this many slots the table stores only occupied buckets; the
/// FPE BRAM tables stay dense (fast, index-addressed) while a
/// paper-scale 8 GB BPE region does not allocate 8 GB.
const DENSE_SLOT_LIMIT: usize = 1 << 22;

#[derive(Clone, Debug)]
enum Storage {
    /// slots[bucket * spb + i], cursor per bucket.
    Dense(Vec<Option<Slot>>, Vec<u8>),
    Sparse(FxHashMap<u32, Bucket>),
}

/// One engine's hash table (one key-length group).
///
/// The *capacity* models the hardware memory (buckets × slots); the
/// *storage* is sparse (occupied buckets only), so simulating the
/// paper's 8 GB BPE DRAM does not allocate 8 GB — memory is
/// proportional to occupancy while the collision/eviction behaviour is
/// exactly that of the dense layout.
#[derive(Clone, Debug)]
pub struct HashTable {
    slot_key_width: usize,
    slots_per_bucket: usize,
    buckets: usize,
    storage: Storage,
    occupancy: usize,
    pub lookups: u64,
    pub evictions: u64,
}

impl HashTable {
    /// Build a table that fits `mem_bytes` of memory for keys padded to
    /// `slot_key_width`.  At least one bucket is always allocated.
    pub fn with_memory(mem_bytes: u64, slot_key_width: usize, slots_per_bucket: usize) -> Self {
        assert!(slot_key_width % 4 == 0 && slot_key_width > 0);
        assert!(slots_per_bucket > 0);
        let slot_bytes = (slot_key_width + VALUE_BYTES) as u64;
        let total_slots = (mem_bytes / slot_bytes).max(1) as usize;
        let buckets = (total_slots / slots_per_bucket).max(1);
        let storage = if buckets * slots_per_bucket <= DENSE_SLOT_LIMIT {
            Storage::Dense(vec![None; buckets * slots_per_bucket], vec![0; buckets])
        } else {
            Storage::Sparse(FxHashMap::default())
        };
        Self {
            slot_key_width,
            slots_per_bucket,
            buckets,
            storage,
            occupancy: 0,
            lookups: 0,
            evictions: 0,
        }
    }

    pub fn slot_key_width(&self) -> usize {
        self.slot_key_width
    }

    pub fn capacity_pairs(&self) -> usize {
        self.buckets * self.slots_per_bucket
    }

    pub fn occupancy(&self) -> usize {
        self.occupancy
    }

    pub fn mem_bytes(&self) -> u64 {
        (self.capacity_pairs() * (self.slot_key_width + VALUE_BYTES)) as u64
    }

    #[inline]
    fn bucket_of(&self, key: &Key) -> usize {
        (fnv1a_key(key, self.slot_key_width) as usize) % self.buckets
    }

    /// Hash a key for this table's slot width (cacheable by callers).
    #[inline]
    pub fn hash_of(&self, key: &Key) -> u32 {
        fnv1a_key(key, self.slot_key_width)
    }

    /// Offer a pair: aggregate, insert, or evict (Fig. 7).
    /// `evict_old`: true = paper behaviour (resident pair leaves).
    pub fn offer(&mut self, key: Key, value: Value, op: AggOp, evict_old: bool) -> Probe {
        let hash = self.hash_of(&key);
        self.offer_hashed(hash, key, value, op, evict_old)
    }

    /// [`Self::offer`] with the key's hash precomputed (the FPE hash
    /// unit output travels with the pair to the BPE, Fig. 6).
    pub fn offer_hashed(
        &mut self,
        hash: u32,
        key: Key,
        value: Value,
        op: AggOp,
        evict_old: bool,
    ) -> Probe {
        debug_assert!(key.len() <= self.slot_key_width);
        debug_assert_eq!(hash, self.hash_of(&key));
        self.lookups += 1;
        let b = (hash as usize) % self.buckets;
        let spb = self.slots_per_bucket;
        match &mut self.storage {
            Storage::Dense(slots, cursors) => {
                let base = b * spb;
                let mut free: Option<usize> = None;
                for i in base..base + spb {
                    match &mut slots[i] {
                        Some(s) if s.key == key => {
                            s.value = op.combine(s.value, value);
                            return Probe::Aggregated;
                        }
                        Some(_) => {}
                        None => {
                            if free.is_none() {
                                free = Some(i);
                            }
                        }
                    }
                }
                if let Some(i) = free {
                    slots[i] = Some(Slot { key, value, hash });
                    self.occupancy += 1;
                    return Probe::Inserted;
                }
                self.evictions += 1;
                if evict_old {
                    let cursor = &mut cursors[b];
                    let victim_i = base + (*cursor as usize % spb);
                    *cursor = cursor.wrapping_add(1);
                    let old = slots[victim_i].replace(Slot { key, value, hash }).unwrap();
                    Probe::Evicted(old.key, old.value, old.hash)
                } else {
                    Probe::Evicted(key, value, hash)
                }
            }
            Storage::Sparse(occupied) => {
                let bucket = occupied.entry(b as u32).or_default();
                for s in bucket.slots.iter_mut() {
                    if s.key == key {
                        s.value = op.combine(s.value, value);
                        return Probe::Aggregated;
                    }
                }
                if bucket.slots.len() < spb {
                    bucket.slots.push(Slot { key, value, hash });
                    self.occupancy += 1;
                    return Probe::Inserted;
                }
                self.evictions += 1;
                if evict_old {
                    let victim_i = bucket.cursor as usize % spb;
                    bucket.cursor = bucket.cursor.wrapping_add(1);
                    let old = std::mem::replace(
                        &mut bucket.slots[victim_i],
                        Slot { key, value, hash },
                    );
                    Probe::Evicted(old.key, old.value, old.hash)
                } else {
                    Probe::Evicted(key, value, hash)
                }
            }
        }
    }

    /// Read a key's current value (tests / reducer verification).
    pub fn get(&self, key: &Key) -> Option<Value> {
        let b = self.bucket_of(key);
        match &self.storage {
            Storage::Dense(slots, _) => slots[b * self.slots_per_bucket..][..self.slots_per_bucket]
                .iter()
                .flatten()
                .find(|s| s.key == *key)
                .map(|s| s.value),
            Storage::Sparse(occupied) => occupied
                .get(&(b as u32))?
                .slots
                .iter()
                .find(|s| s.key == *key)
                .map(|s| s.value),
        }
    }

    /// Drain all resident pairs (flush to next hop / next stage), in
    /// memory order (bucket index, then slot) — the BPE-Flush stage
    /// streams this out of RAM.
    pub fn drain(&mut self) -> Vec<(Key, Value)> {
        let mut out = Vec::with_capacity(self.occupancy);
        match &mut self.storage {
            Storage::Dense(slots, _) => {
                for s in slots.iter_mut() {
                    if let Some(slot) = s.take() {
                        out.push((slot.key, slot.value));
                    }
                }
            }
            Storage::Sparse(occupied) => {
                let mut ids: Vec<u32> = occupied.keys().copied().collect();
                ids.sort_unstable();
                for id in ids {
                    let bucket = occupied.remove(&id).unwrap();
                    out.extend(bucket.slots.into_iter().map(|s| (s.key, s.value)));
                }
            }
        }
        self.occupancy = 0;
        out
    }

    /// Iterate resident pairs without draining (arbitrary order).
    pub fn iter(&self) -> impl Iterator<Item = (&Key, Value)> + '_ {
        let (dense, sparse): (Option<_>, Option<_>) = match &self.storage {
            Storage::Dense(slots, _) => (Some(slots.iter().flatten()), None),
            Storage::Sparse(occupied) => (
                None,
                Some(occupied.values().flat_map(|b| b.slots.iter())),
            ),
        };
        dense
            .into_iter()
            .flatten()
            .chain(sparse.into_iter().flatten())
            .map(|s| (&s.key, s.value))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(pairs: usize, width: usize, spb: usize) -> HashTable {
        HashTable::with_memory((pairs * (width + VALUE_BYTES)) as u64, width, spb)
    }

    #[test]
    fn memory_accounting_matches_capacity() {
        let t = HashTable::with_memory(4 << 20, 16, 2);
        // 4 MiB / 20 B per slot = 209715 slots -> 104857 buckets * 2.
        assert_eq!(t.capacity_pairs(), 209_714);
        assert!(t.mem_bytes() <= 4 << 20);
    }

    #[test]
    fn aggregate_then_get() {
        let mut t = table(64, 16, 2);
        let k = Key::from_id(5, 12);
        assert_eq!(t.offer(k, 10, AggOp::Sum, true), Probe::Inserted);
        assert_eq!(t.offer(k, 32, AggOp::Sum, true), Probe::Aggregated);
        assert_eq!(t.get(&k), Some(42));
        assert_eq!(t.occupancy(), 1);
    }

    #[test]
    fn eviction_old_vs_new() {
        // 1 bucket, 1 slot: second distinct key must evict.
        let mut t = table(1, 8, 1);
        let k1 = Key::from_id(1, 8);
        let k2 = Key::from_id(2, 8);
        assert_eq!(t.offer(k1, 11, AggOp::Sum, true), Probe::Inserted);
        match t.offer(k2, 22, AggOp::Sum, true) {
            Probe::Evicted(k, v, h) => {
                assert_eq!((k, v), (k1, 11)); // resident pair leaves
                assert_eq!(h, t.hash_of(&k1));
            }
            other => panic!("expected eviction, got {other:?}"),
        }
        assert_eq!(t.get(&k2), Some(22)); // newcomer resident

        let mut t = table(1, 8, 1);
        t.offer(k1, 11, AggOp::Sum, false);
        match t.offer(k2, 22, AggOp::Sum, false) {
            Probe::Evicted(k, v, _) => assert_eq!((k, v), (k2, 22)), // newcomer forwarded
            other => panic!("{other:?}"),
        }
        assert_eq!(t.get(&k1), Some(11));
    }

    #[test]
    fn bucket_scan_finds_second_slot() {
        // 24 bytes = exactly 2 slots of (8B key + 4B value) = 1 bucket.
        let mut t = HashTable::with_memory(24, 8, 2);
        assert_eq!(t.buckets, 1);
        let k1 = Key::from_id(1, 8);
        let k2 = Key::from_id(2, 8);
        assert_eq!(t.offer(k1, 1, AggOp::Sum, true), Probe::Inserted);
        assert_eq!(t.offer(k2, 2, AggOp::Sum, true), Probe::Inserted);
        assert_eq!(t.offer(k2, 3, AggOp::Sum, true), Probe::Aggregated);
        assert_eq!(t.get(&k2), Some(5));
        // Third key: round-robin eviction rotates victims.
        let k3 = Key::from_id(3, 8);
        let Probe::Evicted(v1, _, _) = t.offer(k3, 9, AggOp::Sum, true) else {
            panic!()
        };
        let k4 = Key::from_id(4, 8);
        let Probe::Evicted(v2, _, _) = t.offer(k4, 9, AggOp::Sum, true) else {
            panic!()
        };
        assert_ne!(v1, v2, "round-robin should rotate victims");
    }

    #[test]
    fn drain_returns_everything_once() {
        let mut t = table(128, 16, 2);
        let mut inserted = 0;
        for id in 0..80u64 {
            if matches!(
                t.offer(Key::from_id(id, 16), id as Value, AggOp::Sum, true),
                Probe::Inserted
            ) {
                inserted += 1;
            }
        }
        let drained = t.drain();
        assert_eq!(drained.len(), inserted);
        assert_eq!(t.occupancy(), 0);
        assert!(t.drain().is_empty());
    }

    #[test]
    fn value_conservation_under_sum() {
        // sum(inputs) == sum(resident) + sum(evicted) — the invariant
        // that makes in-network SUM correct end-to-end.
        let mut t = table(32, 16, 2);
        let mut evicted_sum: Value = 0;
        let mut input_sum: Value = 0;
        for id in 0..500u64 {
            let v = (id % 13) as Value;
            input_sum += v;
            if let Probe::Evicted(_, ev, _) = t.offer(Key::from_id(id % 97, 16), v, AggOp::Sum, true)
            {
                evicted_sum += ev;
            }
        }
        let resident_sum: Value = t.iter().map(|(_, v)| v).sum();
        assert_eq!(input_sum, resident_sum + evicted_sum);
    }
}
