//! Routing + forwarding module (§4.2.1): L2/L3 table lookup for normal
//! packets, aggregation-tree parent port for aggregation packets.

use crate::net::{NodeId, PortId};
use crate::protocol::TreeId;
use std::collections::BTreeMap;

/// Static routing table: destination node → output port, disseminated
/// by the controller (§4.1 "Routing").
#[derive(Clone, Debug, Default)]
pub struct Forwarding {
    routes: BTreeMap<NodeId, PortId>,
    tree_parent: BTreeMap<TreeId, PortId>,
    pub forwarded: u64,
    pub dropped: u64,
}

impl Forwarding {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn install_route(&mut self, dst: NodeId, port: PortId) {
        self.routes.insert(dst, port);
    }

    pub fn install_tree_parent(&mut self, tree: TreeId, port: PortId) {
        self.tree_parent.insert(tree, port);
    }

    /// Output port for a normal packet.
    pub fn lookup(&mut self, dst: NodeId) -> Option<PortId> {
        match self.routes.get(&dst) {
            Some(&p) => {
                self.forwarded += 1;
                Some(p)
            }
            None => {
                self.dropped += 1;
                None
            }
        }
    }

    /// Output port for an aggregation packet: the tree's parent (§4.2.1
    /// "its output port is determined by the configuration tree").
    pub fn tree_port(&self, tree: TreeId) -> Option<PortId> {
        self.tree_parent.get(&tree).copied()
    }

    pub fn n_routes(&self) -> usize {
        self.routes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_and_counts() {
        let mut f = Forwarding::new();
        f.install_route(NodeId(7), 2);
        assert_eq!(f.lookup(NodeId(7)), Some(2));
        assert_eq!(f.lookup(NodeId(9)), None);
        assert_eq!(f.forwarded, 1);
        assert_eq!(f.dropped, 1);
    }

    #[test]
    fn tree_parent_ports() {
        let mut f = Forwarding::new();
        f.install_tree_parent(TreeId(1), 3);
        assert_eq!(f.tree_port(TreeId(1)), Some(3));
        assert_eq!(f.tree_port(TreeId(2)), None);
    }
}
