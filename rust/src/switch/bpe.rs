//! Back-end processing engine (BPE, §4.2.4, Fig. 6, Fig. 8b).
//!
//! One BPE digests the pairs evicted by all FPEs.  Its memory is the
//! large back-end DRAM, divided into per-group regions laid out like
//! the FPE tables (`[region base + key range base + key index]`, §5).
//! The memory controller buffers read/write commands (`sim::dram`) so
//! key processing is *pipelined*: a DRAM access in flight does not
//! block the next pair — this is what hides the ~25-cycle DRAM latency
//! and keeps the hierarchy at line rate.

use crate::protocol::{AggOp, Key, Value};
use crate::sim::clock::Cycles;
use crate::sim::dram::DramModel;
use crate::switch::config::{EvictionPolicy, StageDelays, SwitchConfig};
use crate::switch::hash_table::{HashTable, LaneProbe, Probe, VectorEvictSink};
use crate::util::codec::{self, SnapCursor, SnapshotError};

/// What happened to a pair offered to the BPE.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BpeOutcome {
    Kept,
    /// Even the back-end is full for this bucket: the pair leaves the
    /// switch towards the next hop at `ready`.
    Overflow { key: Key, value: Value, ready: Cycles },
}

#[derive(Clone, Debug)]
pub struct Bpe {
    /// One region per key-length group (Fig. 8b).
    regions: Vec<HashTable>,
    dram: DramModel,
    interval: Cycles,
    delays: StageDelays,
    eviction: EvictionPolicy,
    fifo_cap: usize,
    busy_until: Cycles,
    pub fifo_writes: u64,
    pub fifo_full_events: u64,
    /// Peak input-FIFO occupancy ever observed (capped at `fifo_cap`;
    /// see `Fpe::fifo_peak`).
    pub fifo_peak: u64,
    pub aggregated: u64,
    pub inserted: u64,
    pub overflowed: u64,
    pub latency_cycles: u64,
}

impl Bpe {
    /// Build from a switch config and this tree's DRAM share.
    pub fn for_tree(cfg: &SwitchConfig, mem_share: u64) -> Self {
        Self::for_tree_lanes(cfg, mem_share, 1)
    }

    /// [`Self::for_tree`] with `lanes` value lanes per slot — every
    /// region is a W-lane table, so evicted vector pairs digest here
    /// exactly like scalars do.
    pub fn for_tree_lanes(cfg: &SwitchConfig, mem_share: u64, lanes: usize) -> Self {
        let per_region = mem_share / cfg.n_groups as u64;
        let regions = (0..cfg.n_groups)
            .map(|g| {
                HashTable::with_memory_lanes(
                    per_region,
                    cfg.group_width(g),
                    cfg.bpe_slots_per_bucket,
                    lanes,
                )
            })
            .collect();
        Self {
            regions,
            dram: DramModel::new(cfg.dram.clone()),
            interval: cfg.bpe_interval,
            delays: cfg.delays,
            eviction: cfg.eviction,
            fifo_cap: cfg.fifo_cap,
            busy_until: 0,
            fifo_writes: 0,
            fifo_full_events: 0,
            fifo_peak: 0,
            aggregated: 0,
            inserted: 0,
            overflowed: 0,
            latency_cycles: 0,
        }
    }

    pub fn region(&self, group: usize) -> &HashTable {
        &self.regions[group]
    }

    /// Mutable per-group region access for the sharded ingest engine:
    /// workers own disjoint regions and run the functional probes
    /// there, while the shared timing is replayed via
    /// [`Self::replay_timing`].
    pub(crate) fn regions_mut(&mut self) -> &mut [HashTable] {
        &mut self.regions
    }

    /// The eviction policy this engine probes with.
    pub fn eviction(&self) -> EvictionPolicy {
        self.eviction
    }

    /// Verification read with the FPE hash-unit output supplied —
    /// regions share the FPE slot widths, so the tag is identical and
    /// the lookup never rehashes the key.
    pub fn get_hashed(&self, group: usize, hash: u32, key: &Key) -> Option<Value> {
        self.regions[group].get_hashed(hash, key)
    }

    pub fn occupancy_pairs(&self) -> usize {
        self.regions.iter().map(|r| r.occupancy()).sum()
    }

    pub fn capacity_pairs(&self) -> usize {
        self.regions.iter().map(|r| r.capacity_pairs()).sum()
    }

    /// FIFO occupancy at cycle `at` (closed form; see `Fpe`).
    pub fn fifo_depth_at(&self, at: Cycles) -> usize {
        if self.busy_until <= at {
            0
        } else {
            (self.busy_until - at).div_ceil(self.interval) as usize
        }
    }

    pub fn fifo_depth(&self) -> usize {
        self.fifo_depth_at(self.busy_until.saturating_sub(1))
    }

    /// Offer an evicted pair arriving from the scheduler at `arrive`.
    pub fn offer(
        &mut self,
        arrive: Cycles,
        group: usize,
        key: Key,
        value: Value,
        op: AggOp,
    ) -> BpeOutcome {
        let hash = self.regions[group].hash_of(&key);
        self.offer_hashed(arrive, group, key, value, hash, op)
    }

    /// [`Self::offer`] with the FPE hash-unit output supplied (regions
    /// share the FPE's slot width, so the hash is identical).
    pub fn offer_hashed(
        &mut self,
        arrive: Cycles,
        group: usize,
        key: Key,
        value: Value,
        hash: u32,
        op: AggOp,
    ) -> BpeOutcome {
        let start = self.replay_timing(arrive);
        let evict_old = self.eviction == EvictionPolicy::EvictOld;
        match self.regions[group].offer_hashed(hash, key, value, op, evict_old) {
            Probe::Aggregated => {
                self.aggregated += 1;
                BpeOutcome::Kept
            }
            Probe::Inserted => {
                self.inserted += 1;
                BpeOutcome::Kept
            }
            Probe::Evicted(k, v, _) => {
                self.overflowed += 1;
                BpeOutcome::Overflow {
                    key: k,
                    value: v,
                    ready: start + self.delays.bpe_aggregate,
                }
            }
        }
    }

    /// Digest one W-lane evictee (key + FPE hash-unit tag + lanes)
    /// arriving from the scheduler at `arrive`.  Timing is exactly
    /// [`Self::offer_hashed`]'s ([`Self::replay_timing`]); on a full
    /// back-end bucket the W-lane overflow pair is appended to the
    /// caller's sink and its switch-exit cycle returned.
    pub fn offer_lanes_hashed(
        &mut self,
        arrive: Cycles,
        group: usize,
        evictee: (Key, u32),
        lanes: &[Value],
        op: AggOp,
        overflow: &mut VectorEvictSink,
    ) -> Option<Cycles> {
        let (key, hash) = evictee;
        let start = self.replay_timing(arrive);
        let evict_old = self.eviction == EvictionPolicy::EvictOld;
        match self.regions[group].offer_lanes_hashed(hash, key, lanes, op, evict_old, overflow) {
            LaneProbe::Aggregated => {
                self.aggregated += 1;
                None
            }
            LaneProbe::Inserted => {
                self.inserted += 1;
                None
            }
            LaneProbe::Evicted => {
                self.overflowed += 1;
                Some(start + self.delays.bpe_aggregate)
            }
        }
    }

    /// The timing half of [`Self::offer_hashed`] — FIFO accounting,
    /// busy chain, the two DRAM commands, and the pair latency — for
    /// one arrival at `arrive`; returns the service start cycle.
    ///
    /// The sharded ingest engine runs the functional probes on
    /// per-group region shards in parallel and then calls this in
    /// *global eviction order* during its merge stage, so the shared
    /// timing counters (FIFO writes/full events, DRAM issue/stall,
    /// latency) stay byte-identical to the serial path.  The probe
    /// outcome never feeds back into the timing, which is what makes
    /// the split exact.
    pub(crate) fn replay_timing(&mut self, arrive: Cycles) -> Cycles {
        let mut effective_arrive = arrive;
        let depth = self.fifo_depth_at(arrive);
        if depth >= self.fifo_cap {
            self.fifo_full_events += 1;
            let oldest = self.busy_until - (depth as Cycles - 1) * self.interval;
            effective_arrive = effective_arrive.max(oldest);
        }
        self.fifo_writes += 1;
        self.fifo_peak = self.fifo_peak.max((depth + 1).min(self.fifo_cap) as u64);

        let start = effective_arrive.max(self.busy_until);
        // Two DRAM commands per pair (bucket read + write-back); the
        // command buffer may defer the issue but does not stall the
        // engine unless it is full.
        let (_, _read_done) = self.dram.access(start);
        let (_, _write_done) = self.dram.access(start + 1);
        self.busy_until = start + self.interval;
        self.latency_cycles += self.delays.bpe_aggregate;
        start
    }

    /// Rebuild the per-group DRAM regions at a new memory share (quota
    /// resize), draining every resident pair into `out` for software
    /// merge.  The DRAM model, lifetime combine count and all engine
    /// counters survive — like `Fpe::replace_table`, a resize is a
    /// memory management event, not a pipeline event.
    pub(crate) fn rebuild_regions(
        &mut self,
        cfg: &SwitchConfig,
        mem_share: u64,
        lanes: usize,
        out: &mut Vec<(Key, Value)>,
    ) {
        let combines: u64 = self.regions.iter().map(|r| r.combines).sum();
        let saturated: u64 = self.regions.iter().map(|r| r.saturated).sum();
        for r in &mut self.regions {
            r.drain_into(out);
        }
        let per_region = mem_share / cfg.n_groups as u64;
        self.regions = (0..cfg.n_groups)
            .map(|g| {
                HashTable::with_memory_lanes(
                    per_region,
                    cfg.group_width(g),
                    cfg.bpe_slots_per_bucket,
                    lanes,
                )
            })
            .collect();
        // `agg_ops`/`saturated_ops` sum the regions' accounting points;
        // park the lifetime counts on region 0 so the sums are
        // unchanged.  Audit digests start fresh at zero (the drains
        // zeroed the old ones).
        self.regions[0].combines = combines;
        self.regions[0].saturated = saturated;
    }

    /// Fold shard-worker probe outcome counts back into the engine
    /// (the counterpart of the probes run on [`Self::regions_mut`]).
    pub(crate) fn absorb_probe_counts(&mut self, aggregated: u64, inserted: u64, overflowed: u64) {
        self.aggregated += aggregated;
        self.inserted += inserted;
        self.overflowed += overflowed;
    }

    /// Flush all regions; returns the resident pairs and the stream-out
    /// cycles.  The memory management maintains per-region base
    /// pointers and key indices (§5), so the flush streams the
    /// *occupied* slots out of DRAM; Table 3's huge `BPE-Flush` row
    /// (3.125e7 cycles = 500 MB of beats) is the occupancy of the
    /// paper's 1 GB-key-variety run, not the whole 8 GB region.
    pub fn flush(&mut self) -> (Vec<(Key, Value)>, Cycles) {
        let mut pairs = Vec::with_capacity(self.occupancy_pairs());
        let cycles = self.flush_into(&mut pairs);
        (pairs, cycles)
    }

    /// [`Self::flush`] appending into a caller-owned buffer (the
    /// zero-alloc ingest path reuses one scratch across engines).
    pub fn flush_into(&mut self, out: &mut Vec<(Key, Value)>) -> Cycles {
        let cycles = self.flush_occupied_cycles();
        for r in &mut self.regions {
            r.drain_into(out);
        }
        cycles
    }

    /// Columnar flush for W-lane regions: drain every region into
    /// caller-owned key/lane buffers; same occupancy-proportional
    /// stream-out cost scaled by the wider slots.
    pub fn flush_lanes_into(&mut self, keys: &mut Vec<Key>, vals: &mut Vec<Value>) -> Cycles {
        let cycles = self.flush_occupied_cycles();
        for r in &mut self.regions {
            r.drain_lanes_into(keys, vals);
        }
        cycles
    }

    /// Flush cost streaming only the occupied slots.
    pub fn flush_occupied_cycles(&self) -> Cycles {
        let bytes: u64 = self
            .regions
            .iter()
            .map(|r| (r.occupancy() * r.slot_bytes()) as u64)
            .sum();
        self.dram.stream_out_cycles(bytes)
    }

    /// Naive flush cost scanning the entire allocated region (the
    /// unoptimized variant, kept for the perf ablation).
    pub fn flush_region_scan_cycles(&self) -> Cycles {
        let bytes: u64 = self.regions.iter().map(|r| r.mem_bytes()).sum();
        self.dram.stream_out_cycles(bytes)
    }

    pub fn full_ratio(&self) -> f64 {
        if self.fifo_writes == 0 {
            0.0
        } else {
            self.fifo_full_events as f64 / self.fifo_writes as f64
        }
    }

    pub fn dram_stats(&self) -> (u64, Cycles) {
        (self.dram.issued, self.dram.stall_cycles)
    }

    /// Aggregation-ALU lane-combines across all regions, read from the
    /// tables' single accounting point (`HashTable::combines`) — see
    /// `Fpe::agg_ops`.
    pub fn agg_ops(&self) -> u64 {
        self.regions.iter().map(|r| r.combines).sum()
    }

    /// Saturating lane-combines across all regions (see
    /// `HashTable::saturated`).
    pub fn saturated_ops(&self) -> u64 {
        self.regions.iter().map(|r| r.saturated).sum()
    }

    /// Verify every DRAM region's audit digest; `Err((group, expected,
    /// computed))` names the first region whose memory changed outside
    /// the aggregation datapath.
    pub fn audit(&self) -> Result<(), (usize, u64, u64)> {
        for (g, r) in self.regions.iter().enumerate() {
            if let Err((expected, computed)) = r.audit() {
                return Err((g, expected, computed));
            }
        }
        Ok(())
    }

    /// Inject one seeded bit flip into the first non-empty region
    /// (rotating by seed), bypassing the audit digests; `false` if
    /// every region was empty.
    pub fn poison_bit(&mut self, seed: u64) -> bool {
        let n = self.regions.len();
        for i in 0..n {
            let g = (seed as usize + i) % n;
            if self.regions[g].poison_bit(seed) {
                return true;
            }
        }
        false
    }

    /// Serialize the engine meta state — busy chain, counters, DRAM
    /// controller — *without* the regions; the per-group region tables
    /// are serialized as their own snapshot sections so incremental
    /// checkpoints can ship only the regions that changed.
    pub(crate) fn snapshot_write_meta(&self, out: &mut Vec<u8>) {
        codec::put_u64(out, self.busy_until);
        codec::put_u64(out, self.fifo_writes);
        codec::put_u64(out, self.fifo_full_events);
        codec::put_u64(out, self.fifo_peak);
        codec::put_u64(out, self.aggregated);
        codec::put_u64(out, self.inserted);
        codec::put_u64(out, self.overflowed);
        codec::put_u64(out, self.latency_cycles);
        self.dram.snapshot_write(out);
    }

    /// Restore meta state written by [`Self::snapshot_write_meta`].
    pub(crate) fn snapshot_read_meta(
        &mut self,
        cur: &mut SnapCursor<'_>,
    ) -> Result<(), SnapshotError> {
        self.busy_until = cur.u64()?;
        self.fifo_writes = cur.u64()?;
        self.fifo_full_events = cur.u64()?;
        self.fifo_peak = cur.u64()?;
        self.aggregated = cur.u64()?;
        self.inserted = cur.u64()?;
        self.overflowed = cur.u64()?;
        self.latency_cycles = cur.u64()?;
        self.dram.snapshot_read_into(cur)
    }

    /// Number of per-group DRAM regions (one snapshot section each).
    pub(crate) fn n_regions(&self) -> usize {
        self.regions.len()
    }

    /// Serialize one region table (its own snapshot section).
    pub(crate) fn snapshot_write_region(&self, group: usize, out: &mut Vec<u8>) {
        self.regions[group].snapshot_write(out);
    }

    /// Restore one region table in place.
    pub(crate) fn snapshot_read_region(
        &mut self,
        group: usize,
        cur: &mut SnapCursor<'_>,
    ) -> Result<(), SnapshotError> {
        self.regions[group].snapshot_read_into(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::dram::DramConfig;

    fn small_bpe(mem: u64) -> Bpe {
        let cfg = SwitchConfig {
            bpe_mem: Some(mem),
            dram: DramConfig {
                latency: 25,
                queue_depth: 32,
                service_interval: 2,
            },
            ..SwitchConfig::default()
        };
        Bpe::for_tree(&cfg, mem)
    }

    #[test]
    fn regions_partition_memory() {
        let b = small_bpe(8 << 20);
        assert_eq!(b.regions.len(), 8);
        // Wider-key regions hold fewer pairs for the same bytes.
        assert!(b.region(0).capacity_pairs() > b.region(7).capacity_pairs());
        assert!(b.capacity_pairs() > 0);
    }

    #[test]
    fn keeps_and_aggregates() {
        let mut b = small_bpe(1 << 20);
        let k = Key::from_id(9, 16);
        assert_eq!(b.offer(0, 1, k, 5, AggOp::Sum), BpeOutcome::Kept);
        assert_eq!(b.offer(50, 1, k, 6, AggOp::Sum), BpeOutcome::Kept);
        assert_eq!(b.region(1).get(&k), Some(11));
        let h = b.region(1).hash_of(&k);
        assert_eq!(b.get_hashed(1, h, &k), Some(11));
        assert_eq!(b.aggregated, 1);
        assert_eq!(b.inserted, 1);
        let (issued, _) = b.dram_stats();
        assert_eq!(issued, 4); // 2 commands per pair
    }

    #[test]
    fn tiny_region_overflows_to_output() {
        // 1 pair per region; forcing two distinct same-bucket keys out.
        let cfg = SwitchConfig {
            bpe_slots_per_bucket: 1,
            ..SwitchConfig::default()
        };
        let mut b = Bpe::for_tree(&cfg, (8 * 20) as u64); // ~1 slot/region
        let mut overflowed = 0;
        for id in 0..50u64 {
            if let BpeOutcome::Overflow { .. } = b.offer(id * 10, 1, Key::from_id(id, 16), 1, AggOp::Sum)
            {
                overflowed += 1;
            }
        }
        assert!(overflowed > 0);
        assert_eq!(overflowed, b.overflowed);
    }

    #[test]
    fn flush_cost_scales_with_occupancy_not_region() {
        let mut b = small_bpe(1 << 20);
        b.offer(0, 0, Key::from_id(1, 8), 1, AggOp::Sum);
        let region_scan = b.flush_region_scan_cycles();
        let (pairs, cost) = b.flush();
        assert_eq!(pairs.len(), 1);
        // One resident pair: occupancy flush ≈ latency; region scan huge.
        assert!(cost < 100, "occupancy flush {cost}");
        assert!(region_scan > cost * 100);
    }

    #[test]
    fn lane_digest_matches_scalar_at_w1_and_counts_combines() {
        let cfg = SwitchConfig::default();
        let mut scalar = Bpe::for_tree(&cfg, 1 << 20);
        let mut lane = Bpe::for_tree_lanes(&cfg, 1 << 20, 1);
        let mut sink = VectorEvictSink::new();
        for id in 0..200u64 {
            let k = Key::from_id(id % 40, 16);
            let h = scalar.region(1).hash_of(&k);
            let s = scalar.offer_hashed(id * 5, 1, k, 2, h, AggOp::Sum);
            let l = lane.offer_lanes_hashed(id * 5, 1, (k, h), &[2], AggOp::Sum, &mut sink);
            match (s, l) {
                (BpeOutcome::Kept, None) => {}
                (BpeOutcome::Overflow { ready, .. }, Some(lready)) => assert_eq!(ready, lready),
                other => panic!("paths diverged: {other:?}"),
            }
        }
        assert_eq!(
            (scalar.aggregated, scalar.inserted, scalar.overflowed),
            (lane.aggregated, lane.inserted, lane.overflowed)
        );
        assert_eq!(scalar.dram_stats(), lane.dram_stats());
        assert_eq!(scalar.agg_ops(), lane.agg_ops());
        assert_eq!(scalar.agg_ops(), scalar.aggregated, "one combine per hit");

        // 8-lane digest: combines scale by W, flush is columnar.
        let mut wide = Bpe::for_tree_lanes(&cfg, 1 << 20, 8);
        let k = Key::from_id(7, 16);
        let h = wide.region(1).hash_of(&k);
        let lanes = [3i64; 8];
        wide.offer_lanes_hashed(0, 1, (k, h), &lanes, AggOp::Sum, &mut sink);
        wide.offer_lanes_hashed(10, 1, (k, h), &lanes, AggOp::Sum, &mut sink);
        assert_eq!(wide.agg_ops(), 8);
        let mut keys = Vec::new();
        let mut vals = Vec::new();
        wide.flush_lanes_into(&mut keys, &mut vals);
        assert_eq!(keys, vec![k]);
        assert_eq!(vals, vec![6i64; 8]);
    }

    #[test]
    fn rebuild_regions_preserves_counters_and_dram_state() {
        let cfg = SwitchConfig::default();
        let mut b = Bpe::for_tree(&cfg, 1 << 20);
        for id in 0..20u64 {
            b.offer(id * 4, 1, Key::from_id(id % 6, 16), 1, AggOp::Sum);
        }
        let counters = (b.aggregated, b.inserted, b.overflowed, b.fifo_writes);
        let ops = b.agg_ops();
        let dram = b.dram_stats();
        let lat = b.latency_cycles;

        let mut spilled = Vec::new();
        b.rebuild_regions(&cfg, 8 * 68, 1, &mut spilled);
        assert_eq!(spilled.len(), 6, "residents drained, not dropped");
        assert_eq!(b.occupancy_pairs(), 0);
        assert_eq!(b.regions.len(), cfg.n_groups);

        assert_eq!(
            (b.aggregated, b.inserted, b.overflowed, b.fifo_writes),
            counters
        );
        assert_eq!(b.agg_ops(), ops, "lifetime combine count survives");
        assert_eq!(b.dram_stats(), dram, "DRAM model untouched");
        assert_eq!(b.latency_cycles, lat);
    }

    #[test]
    fn pipelined_offers_do_not_serialize_on_dram_latency() {
        let mut b = small_bpe(1 << 20);
        for id in 0..100u64 {
            b.offer(id * 4, 0, Key::from_id(id, 8), 1, AggOp::Sum);
        }
        // busy_until advanced by interval (4), not by DRAM latency (25).
        assert_eq!(b.fifo_full_events, 0);
        let (_, stalls) = b.dram_stats();
        assert!(stalls < 100 * 25 / 2, "DRAM latency not hidden: {stalls}");
    }
}
