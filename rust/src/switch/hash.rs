//! The hash function unit (§4.2.4): word-level FNV-1a-32.
//!
//! Bit-identical to the Pallas kernel in
//! `python/compile/kernels/hash_fnv.py` — keys are zero-padded to the
//! slot width of their group (a multiple of 4 bytes) and hashed as
//! little-endian 32-bit words.  `integration_runtime.rs` asserts
//! equality across the language boundary through the AOT artifact.

use crate::protocol::Key;

pub const FNV_OFFSET: u32 = 2_166_136_261;
pub const FNV_PRIME: u32 = 16_777_619;

/// FNV-1a-32 over 32-bit words.
#[inline]
pub fn fnv1a_words(words: &[u32]) -> u32 {
    let mut h = FNV_OFFSET;
    for &w in words {
        h = (h ^ w).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Hash a key padded to `width` bytes (the group's slot width), without
/// allocating: iterates 4-byte chunks of the padded representation.
#[inline]
pub fn fnv1a_key(key: &Key, width: usize) -> u32 {
    debug_assert!(width % 4 == 0 && width >= key.len());
    let bytes = key.as_bytes();
    let mut h = FNV_OFFSET;
    let mut i = 0;
    while i < width {
        let mut wb = [0u8; 4];
        if i < bytes.len() {
            let n = (bytes.len() - i).min(4);
            wb[..n].copy_from_slice(&bytes[i..i + n]);
        }
        h = (h ^ u32::from_le_bytes(wb)).wrapping_mul(FNV_PRIME);
        i += 4;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinned_vectors_match_python() {
        // Pinned in python/tests/test_kernel.py::test_fnv_known_vector.
        assert_eq!(fnv1a_words(&[0]), 84_696_351);
        let h = fnv1a_words(&[0xDEAD_BEEF, 0x1234_5678]);
        // Recompute longhand.
        let step1 = (FNV_OFFSET ^ 0xDEAD_BEEFu32).wrapping_mul(FNV_PRIME);
        let step2 = (step1 ^ 0x1234_5678).wrapping_mul(FNV_PRIME);
        assert_eq!(h, step2);
    }

    #[test]
    fn key_hash_equals_packed_words_hash() {
        for len in [1usize, 5, 8, 23, 64] {
            let key = Key::from_id(len as u64, len);
            let width = len.div_ceil(8).max(1) * 8;
            let words = key.packed_words(width);
            assert_eq!(fnv1a_key(&key, width), fnv1a_words(&words), "len {len}");
        }
    }

    #[test]
    fn width_affects_hash() {
        // Same key padded to different group widths hashes differently:
        // the payload analyzer must route a key consistently.
        let key = Key::new(b"hello");
        assert_ne!(fnv1a_key(&key, 8), fnv1a_key(&key, 16));
    }

    #[test]
    fn distribution_spreads_buckets() {
        let buckets = 256usize;
        let mut counts = vec![0usize; buckets];
        for id in 0..100_000u64 {
            let key = Key::from_id(id, 16);
            counts[(fnv1a_key(&key, 16) as usize) % buckets] += 1;
        }
        let (min, max) = counts
            .iter()
            .fold((usize::MAX, 0), |(lo, hi), &c| (lo.min(c), hi.max(c)));
        // Expected 390 per bucket; allow generous spread.
        assert!(min > 250 && max < 550, "min={min} max={max}");
    }
}
