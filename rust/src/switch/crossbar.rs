//! Crossbar (Fig. 5b): transfers grouped pairs from the payload
//! analyzers to their dedicated processing engines.
//!
//! Timing model: the crossbar adds a fixed 2-cycle traversal (Table 3)
//! and serializes deliveries *per output* — two pairs bound for the
//! same FPE in the same cycle arrive back-to-back, which is where input
//! FIFO pressure comes from.

use crate::sim::Cycles;
use crate::util::codec::{self, SnapCursor, SnapshotError};

/// Per-output-port serialization state.
#[derive(Clone, Debug)]
pub struct Crossbar {
    latency: Cycles,
    /// Earliest cycle each output can accept the next pair.
    next_free: Vec<Cycles>,
    pub transfers: u64,
    pub contended: u64,
}

impl Crossbar {
    pub fn new(n_outputs: usize, latency: Cycles) -> Self {
        Self {
            latency,
            next_free: vec![0; n_outputs],
            transfers: 0,
            contended: 0,
        }
    }

    pub fn n_outputs(&self) -> usize {
        self.next_free.len()
    }

    /// Route one pair arriving at `now` to output `out`; returns its
    /// delivery cycle at the FPE input.
    pub fn route(&mut self, now: Cycles, out: usize) -> Cycles {
        let start = now.max(self.next_free[out]);
        if start > now {
            self.contended += 1;
        }
        // One pair per cycle per output once the path is free.
        self.next_free[out] = start + 1;
        self.transfers += 1;
        start + self.latency
    }

    pub fn reset(&mut self) {
        self.next_free.fill(0);
        self.transfers = 0;
        self.contended = 0;
    }

    /// Detach a single-output replica for a shard worker.  The view
    /// starts from this output's current serialization state and counts
    /// its own transfers/contentions; [`Self::absorb`] folds it back.
    /// Outputs are independent in [`Self::route`] (per-output
    /// `next_free`), so views over distinct outputs replay the serial
    /// crossbar exactly regardless of worker interleaving.
    pub fn port_view(&self, out: usize) -> PortView {
        PortView {
            latency: self.latency,
            next_free: self.next_free[out],
            transfers: 0,
            contended: 0,
        }
    }

    /// Reattach a worker's [`PortView`] for `out`.
    pub fn absorb(&mut self, out: usize, view: PortView) {
        self.next_free[out] = view.next_free;
        self.transfers += view.transfers;
        self.contended += view.contended;
    }

    /// Serialize the per-output serialization state and counters (the
    /// latency is static configuration and not serialized).
    pub(crate) fn snapshot_write(&self, out: &mut Vec<u8>) {
        codec::put_u64(out, self.transfers);
        codec::put_u64(out, self.contended);
        for &nf in &self.next_free {
            codec::put_u64(out, nf);
        }
    }

    /// Restore state written by [`Self::snapshot_write`] in place; the
    /// output count is fixed by construction, so no length rides the
    /// wire.
    pub(crate) fn snapshot_read_into(
        &mut self,
        cur: &mut SnapCursor<'_>,
    ) -> Result<(), SnapshotError> {
        self.transfers = cur.u64()?;
        self.contended = cur.u64()?;
        for nf in &mut self.next_free {
            *nf = cur.u64()?;
        }
        Ok(())
    }
}

/// One output's slice of the crossbar, owned by a shard worker (see
/// [`Crossbar::port_view`]).
#[derive(Clone, Copy, Debug)]
pub struct PortView {
    latency: Cycles,
    next_free: Cycles,
    transfers: u64,
    contended: u64,
}

impl PortView {
    /// Identical arithmetic to [`Crossbar::route`] for this output.
    #[inline]
    pub fn route(&mut self, now: Cycles) -> Cycles {
        let start = now.max(self.next_free);
        if start > now {
            self.contended += 1;
        }
        self.next_free = start + 1;
        self.transfers += 1;
        start + self.latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adds_latency() {
        let mut x = Crossbar::new(8, 2);
        assert_eq!(x.route(10, 3), 12);
        assert_eq!(x.transfers, 1);
        assert_eq!(x.contended, 0);
    }

    #[test]
    fn serializes_same_output() {
        let mut x = Crossbar::new(2, 2);
        assert_eq!(x.route(0, 0), 2);
        assert_eq!(x.route(0, 0), 3); // queued behind the first
        assert_eq!(x.contended, 1);
        // Different output: no contention.
        assert_eq!(x.route(0, 1), 2);
        assert_eq!(x.contended, 1);
    }

    #[test]
    fn path_frees_over_time() {
        let mut x = Crossbar::new(1, 2);
        x.route(0, 0);
        assert_eq!(x.route(100, 0), 102);
        assert_eq!(x.contended, 0);
    }

    #[test]
    fn port_view_replays_route_exactly() {
        let mut whole = Crossbar::new(4, 2);
        let mut split = Crossbar::new(4, 2);
        // Warm both with identical traffic.
        for (now, out) in [(0u64, 1usize), (0, 1), (5, 3), (5, 1)] {
            whole.route(now, out);
            split.route(now, out);
        }
        // Continue output 1 through a detached view, output 3 directly.
        let mut v1 = split.port_view(1);
        let arrivals = [6u64, 6, 7, 40];
        let want: Vec<Cycles> = arrivals.iter().map(|&t| whole.route(t, 1)).collect();
        let got: Vec<Cycles> = arrivals.iter().map(|&t| v1.route(t)).collect();
        assert_eq!(got, want);
        assert_eq!(whole.route(8, 3), split.route(8, 3));
        split.absorb(1, v1);
        assert_eq!(split.transfers, whole.transfers);
        assert_eq!(split.contended, whole.contended);
        // Post-absorb, both crossbars continue identically.
        assert_eq!(whole.route(41, 1), split.route(41, 1));
    }
}
