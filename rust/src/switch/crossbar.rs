//! Crossbar (Fig. 5b): transfers grouped pairs from the payload
//! analyzers to their dedicated processing engines.
//!
//! Timing model: the crossbar adds a fixed 2-cycle traversal (Table 3)
//! and serializes deliveries *per output* — two pairs bound for the
//! same FPE in the same cycle arrive back-to-back, which is where input
//! FIFO pressure comes from.

use crate::sim::Cycles;

/// Per-output-port serialization state.
#[derive(Clone, Debug)]
pub struct Crossbar {
    latency: Cycles,
    /// Earliest cycle each output can accept the next pair.
    next_free: Vec<Cycles>,
    pub transfers: u64,
    pub contended: u64,
}

impl Crossbar {
    pub fn new(n_outputs: usize, latency: Cycles) -> Self {
        Self {
            latency,
            next_free: vec![0; n_outputs],
            transfers: 0,
            contended: 0,
        }
    }

    pub fn n_outputs(&self) -> usize {
        self.next_free.len()
    }

    /// Route one pair arriving at `now` to output `out`; returns its
    /// delivery cycle at the FPE input.
    pub fn route(&mut self, now: Cycles, out: usize) -> Cycles {
        let start = now.max(self.next_free[out]);
        if start > now {
            self.contended += 1;
        }
        // One pair per cycle per output once the path is free.
        self.next_free[out] = start + 1;
        self.transfers += 1;
        start + self.latency
    }

    pub fn reset(&mut self) {
        self.next_free.fill(0);
        self.transfers = 0;
        self.contended = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adds_latency() {
        let mut x = Crossbar::new(8, 2);
        assert_eq!(x.route(10, 3), 12);
        assert_eq!(x.transfers, 1);
        assert_eq!(x.contended, 0);
    }

    #[test]
    fn serializes_same_output() {
        let mut x = Crossbar::new(2, 2);
        assert_eq!(x.route(0, 0), 2);
        assert_eq!(x.route(0, 0), 3); // queued behind the first
        assert_eq!(x.contended, 1);
        // Different output: no contention.
        assert_eq!(x.route(0, 1), 2);
        assert_eq!(x.contended, 1);
    }

    #[test]
    fn path_frees_over_time() {
        let mut x = Crossbar::new(1, 2);
        x.route(0, 0);
        assert_eq!(x.route(100, 0), 102);
        assert_eq!(x.contended, 0);
    }
}
