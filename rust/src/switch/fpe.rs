//! Front-end processing engine (FPE, §4.2.4, Fig. 6–7).
//!
//! Each FPE serves one key-length group with an SRAM (BRAM) hash
//! table: hash → bucket lookup → aggregate on hit, insert on free
//! slot, evict-and-forward on a full bucket.  BRAM reads/writes are
//! single-cycle, so the pipelined engine accepts one pair every
//! `interval` cycles; the stage *latencies* (Table 3) ride on top.
//!
//! Timing is transaction-level: each offered pair carries its arrival
//! cycle; the engine tracks its input-FIFO occupancy by retiring
//! service-completion timestamps, which yields exactly the Table 2
//! counters (writes / full events).

use crate::protocol::{AggOp, Key, Value};
use crate::sim::Cycles;
use crate::switch::config::{EvictionPolicy, StageDelays};
use crate::switch::hash_table::{HashTable, LaneProbe, Probe, VectorEvictSink};
use crate::util::codec::{self, SnapCursor, SnapshotError};

/// What happened to an offered pair.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FpeOutcome {
    /// Aggregated or inserted; nothing leaves the engine.
    Kept,
    /// A pair leaves towards the BPE / output at `ready` (Fig. 7),
    /// carrying its hash-unit output so the BPE need not re-hash.
    Forwarded {
        key: Key,
        value: Value,
        hash: u32,
        ready: Cycles,
    },
}

#[derive(Clone, Debug)]
pub struct Fpe {
    pub group: usize,
    table: HashTable,
    interval: Cycles,
    delays: StageDelays,
    eviction: EvictionPolicy,
    fifo_cap: usize,
    busy_until: Cycles,
    // Table 2 counters.
    pub fifo_writes: u64,
    pub fifo_full_events: u64,
    /// Peak input-FIFO occupancy ever observed (capped at `fifo_cap`,
    /// mirroring `sim::Fifo::max_occupancy` — a refused push stalls
    /// the producer, it does not grow the queue).
    pub fifo_peak: u64,
    // Outcome counters.
    pub aggregated: u64,
    pub inserted: u64,
    pub evicted: u64,
    /// Total pair-latency cycles (for Table 3 averages).
    pub latency_cycles: u64,
}

impl Fpe {
    pub fn new(
        group: usize,
        table: HashTable,
        interval: Cycles,
        delays: StageDelays,
        eviction: EvictionPolicy,
        fifo_cap: usize,
    ) -> Self {
        Self {
            group,
            table,
            interval,
            delays,
            eviction,
            fifo_cap,
            busy_until: 0,
            fifo_writes: 0,
            fifo_full_events: 0,
            fifo_peak: 0,
            aggregated: 0,
            inserted: 0,
            evicted: 0,
            latency_cycles: 0,
        }
    }

    pub fn table(&self) -> &HashTable {
        &self.table
    }

    /// Swap in a replacement SRAM table (quota resize), draining any
    /// resident pairs into `out` for software merge.  Counters, FIFO
    /// state and the busy chain are untouched — a resize is a memory
    /// management event, not a pipeline event.
    pub(crate) fn replace_table(&mut self, table: HashTable, out: &mut Vec<(Key, Value)>) {
        let combines = self.table.combines;
        let saturated = self.table.saturated;
        self.table.drain_into(out);
        self.table = table;
        // `agg_ops`/`saturated` read the table's accounting point;
        // carry the lifetime counts into the replacement.  The audit
        // digest needs no carrying: the drain zeroed the old one and a
        // fresh table starts at zero.
        self.table.combines = combines;
        self.table.saturated = saturated;
    }

    /// FIFO occupancy as seen by an arrival at cycle `at`.
    ///
    /// Completions within one busy period are spaced exactly
    /// `interval` cycles (accepts serialize on `busy_until`), so the
    /// occupancy is the closed form
    /// `ceil((busy_until - at) / interval)` — no per-pair queue needed.
    pub fn fifo_depth_at(&self, at: Cycles) -> usize {
        if self.busy_until <= at {
            0
        } else {
            (self.busy_until - at).div_ceil(self.interval) as usize
        }
    }

    pub fn fifo_depth(&self) -> usize {
        self.fifo_depth_at(self.busy_until.saturating_sub(1))
    }

    /// FIFO/busy-chain admission of one arrival: backpressure
    /// accounting (Table 2 full events) and the pipelined service
    /// start.  Shared by the scalar and W-lane offer paths so their
    /// timing cannot drift.
    fn accept(&mut self, arrive: Cycles) -> Cycles {
        // Backpressure: if the FIFO is full the producer stalls until
        // the oldest pair retires (counted as a full event, Table 2).
        let mut effective_arrive = arrive;
        let depth = self.fifo_depth_at(arrive);
        if depth >= self.fifo_cap {
            self.fifo_full_events += 1;
            // The oldest queued pair completes at
            // busy_until - (depth - 1) * interval.
            let oldest_done = self.busy_until - (depth as Cycles - 1) * self.interval;
            effective_arrive = effective_arrive.max(oldest_done);
        }
        self.fifo_writes += 1;
        self.fifo_peak = self.fifo_peak.max((depth + 1).min(self.fifo_cap) as u64);

        let start = effective_arrive.max(self.busy_until);
        self.busy_until = start + self.interval;
        start
    }

    /// Offer one pair arriving (from the crossbar) at cycle `arrive`.
    pub fn offer(&mut self, arrive: Cycles, key: Key, value: Value, op: AggOp) -> FpeOutcome {
        let start = self.accept(arrive);

        // Functional behaviour.  The hash unit runs once here; its
        // output is the table tag and rides along on eviction.
        let evict_old = self.eviction == EvictionPolicy::EvictOld;
        let hash = self.table.hash_of(&key);
        match self.table.offer_hashed(hash, key, value, op, evict_old) {
            Probe::Aggregated => {
                self.aggregated += 1;
                // Hash + aggregate latency (Table 3 rows 3-4).
                self.latency_cycles += self.delays.fpe_hash + self.delays.fpe_aggregate;
                FpeOutcome::Kept
            }
            Probe::Inserted => {
                self.inserted += 1;
                self.latency_cycles += self.delays.fpe_hash + self.delays.fpe_aggregate;
                FpeOutcome::Kept
            }
            Probe::Evicted(k, v, h) => {
                self.evicted += 1;
                let lat =
                    self.delays.fpe_hash + self.delays.fpe_aggregate + self.delays.fpe_forward;
                self.latency_cycles += lat;
                FpeOutcome::Forwarded {
                    key: k,
                    value: v,
                    hash: h,
                    ready: start + lat,
                }
            }
        }
    }

    /// Offer one W-lane pair.  Timing is identical to [`Self::offer`]
    /// (the engine accepts one *pair* per interval — the W lanes ride
    /// the wide datapath and combine in parallel); on eviction the
    /// W-lane evictee (key + cached tag + lanes) is appended to the
    /// caller's sink and its forward-ready cycle returned.
    pub fn offer_lanes(
        &mut self,
        arrive: Cycles,
        key: Key,
        lanes: &[Value],
        op: AggOp,
        evicted: &mut VectorEvictSink,
    ) -> Option<Cycles> {
        let start = self.accept(arrive);
        let evict_old = self.eviction == EvictionPolicy::EvictOld;
        let hash = self.table.hash_of(&key);
        match self
            .table
            .offer_lanes_hashed(hash, key, lanes, op, evict_old, evicted)
        {
            LaneProbe::Aggregated => {
                self.aggregated += 1;
                self.latency_cycles += self.delays.fpe_hash + self.delays.fpe_aggregate;
                None
            }
            LaneProbe::Inserted => {
                self.inserted += 1;
                self.latency_cycles += self.delays.fpe_hash + self.delays.fpe_aggregate;
                None
            }
            LaneProbe::Evicted => {
                self.evicted += 1;
                let lat =
                    self.delays.fpe_hash + self.delays.fpe_aggregate + self.delays.fpe_forward;
                self.latency_cycles += lat;
                Some(start + lat)
            }
        }
    }

    /// Flush: drain the SRAM table into `out` (appending, so one
    /// scratch buffer serves every engine); returns the stream-out
    /// cycle cost (one 16 B beat per cycle out of BRAM).
    pub fn flush_into(&mut self, out: &mut Vec<(Key, Value)>) -> Cycles {
        let before = out.len();
        self.table.drain_into(out);
        let bytes = ((out.len() - before) * self.table.slot_bytes()) as u64;
        crate::sim::clock::stream_cycles(bytes)
    }

    /// Columnar flush for W-lane tables: drain into caller-owned
    /// key/lane buffers; same stream-out cost model scaled by the
    /// wider slots.
    pub fn flush_lanes_into(&mut self, keys: &mut Vec<Key>, vals: &mut Vec<Value>) -> Cycles {
        let before = keys.len();
        self.table.drain_lanes_into(keys, vals);
        let bytes = ((keys.len() - before) * self.table.slot_bytes()) as u64;
        crate::sim::clock::stream_cycles(bytes)
    }

    /// [`Self::flush_into`] into a fresh vector.
    pub fn flush(&mut self) -> (Vec<(Key, Value)>, Cycles) {
        let mut pairs = Vec::with_capacity(self.table.occupancy());
        let cycles = self.flush_into(&mut pairs);
        (pairs, cycles)
    }

    pub fn full_ratio(&self) -> f64 {
        if self.fifo_writes == 0 {
            0.0
        } else {
            self.fifo_full_events as f64 / self.fifo_writes as f64
        }
    }

    /// Aggregation-ALU lane-combines this engine executed, read from
    /// the table's single accounting point (`HashTable::combines`) so
    /// the count cannot drift from the combines that actually ran —
    /// scalar engines report exactly `aggregated`, W-lane engines
    /// `aggregated × W`.
    pub fn agg_ops(&self) -> u64 {
        self.table.combines
    }

    /// Verify the SRAM region's audit digest (see `HashTable::audit`):
    /// `Err((expected, computed))` means a bit of this engine's table
    /// changed outside the aggregation datapath.
    pub fn audit(&self) -> Result<(), (u64, u64)> {
        self.table.audit()
    }

    /// Inject one seeded SRAM bit flip into this engine's table,
    /// bypassing the audit digest; `false` if the table was empty.
    pub fn poison_bit(&mut self, seed: u64) -> bool {
        self.table.poison_bit(seed)
    }

    /// Serialize the engine's full pipeline state: the busy chain (so
    /// restored FIFO backpressure timing is identical), the Table 2/3
    /// counters, and the SRAM table.  Static configuration (interval,
    /// delays, eviction policy, fifo_cap) is NOT serialized — the
    /// restore target is built from the same `TreeConfig`.
    pub(crate) fn snapshot_write(&self, out: &mut Vec<u8>) {
        codec::put_u64(out, self.busy_until);
        codec::put_u64(out, self.fifo_writes);
        codec::put_u64(out, self.fifo_full_events);
        codec::put_u64(out, self.fifo_peak);
        codec::put_u64(out, self.aggregated);
        codec::put_u64(out, self.inserted);
        codec::put_u64(out, self.evicted);
        codec::put_u64(out, self.latency_cycles);
        self.table.snapshot_write(out);
    }

    /// Restore state written by [`Self::snapshot_write`] in place.
    pub(crate) fn snapshot_read_into(
        &mut self,
        cur: &mut SnapCursor<'_>,
    ) -> Result<(), SnapshotError> {
        self.busy_until = cur.u64()?;
        self.fifo_writes = cur.u64()?;
        self.fifo_full_events = cur.u64()?;
        self.fifo_peak = cur.u64()?;
        self.aggregated = cur.u64()?;
        self.inserted = cur.u64()?;
        self.evicted = cur.u64()?;
        self.latency_cycles = cur.u64()?;
        self.table.snapshot_read_into(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::switch::config::StageDelays;

    fn fpe(pairs: usize, fifo_cap: usize) -> Fpe {
        let table = HashTable::with_memory((pairs * 20) as u64, 16, 2);
        Fpe::new(
            1,
            table,
            2,
            StageDelays::default(),
            EvictionPolicy::EvictOld,
            fifo_cap,
        )
    }

    #[test]
    fn hit_insert_evict_counters() {
        let mut f = fpe(2, 64);
        let k1 = Key::from_id(1, 16);
        assert_eq!(f.offer(0, k1, 5, AggOp::Sum), FpeOutcome::Kept);
        assert_eq!(f.offer(10, k1, 6, AggOp::Sum), FpeOutcome::Kept);
        assert_eq!(f.inserted, 1);
        assert_eq!(f.aggregated, 1);
        assert_eq!(f.table().get(&k1), Some(11));
    }

    #[test]
    fn eviction_forward_has_pipeline_latency() {
        // 1 bucket x 2 slots => third distinct key evicts a resident.
        let mut f = fpe(1, 64);
        let k1 = Key::from_id(1, 16);
        let k2 = Key::from_id(2, 16);
        let k3 = Key::from_id(3, 16);
        f.offer(0, k1, 5, AggOp::Sum);
        f.offer(50, k2, 6, AggOp::Sum);
        match f.offer(100, k3, 7, AggOp::Sum) {
            FpeOutcome::Forwarded {
                key, value, ready, ..
            } => {
                // Round-robin cursor starts at slot 0 -> k1 evicted.
                assert_eq!(key, k1);
                assert_eq!(value, 5);
                // start=100, +10+18+5.
                assert_eq!(ready, 133);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn fifo_fills_under_burst() {
        // interval 2, fifo_cap 4: 20 pairs arriving at the same cycle
        // must generate full events.
        let mut f = fpe(1024, 4);
        for id in 0..20u64 {
            f.offer(0, Key::from_id(id, 16), 1, AggOp::Sum);
        }
        assert_eq!(f.fifo_writes, 20);
        assert!(f.fifo_full_events > 0, "burst should overflow FIFO");
        assert!(f.full_ratio() > 0.0);
        assert_eq!(f.fifo_peak, 4, "peak occupancy caps at fifo_cap");
    }

    #[test]
    fn paced_arrivals_never_fill_fifo() {
        // One pair every 4 cycles into a 2-cycle engine: no pressure.
        let mut f = fpe(1024, 4);
        for id in 0..100u64 {
            f.offer(id * 4, Key::from_id(id, 16), 1, AggOp::Sum);
        }
        assert_eq!(f.fifo_full_events, 0);
        assert_eq!(f.fifo_peak, 1, "paced arrivals never queue behind each other");
    }

    #[test]
    fn flush_drains_and_costs_stream_cycles() {
        let mut f = fpe(64, 64);
        for id in 0..10u64 {
            f.offer(id, Key::from_id(id, 16), 1, AggOp::Sum);
        }
        let (pairs, cycles) = f.flush();
        assert_eq!(pairs.len(), 10);
        // 10 slots * 20B = 200 B = 13 beats.
        assert_eq!(cycles, 13);
        assert_eq!(f.table().occupancy(), 0);
    }

    #[test]
    fn agg_ops_reports_actual_combines() {
        // ISSUE 3 satellite: the engine's op count must equal the
        // combines the table ran, not a bypassed side counter.
        let mut f = fpe(64, 64);
        let k = Key::from_id(1, 16);
        f.offer(0, k, 5, AggOp::Sum);
        assert_eq!(f.agg_ops(), 0, "insert is not a combine");
        f.offer(10, k, 6, AggOp::Sum);
        f.offer(20, k, 7, AggOp::Sum);
        assert_eq!(f.agg_ops(), 2);
        assert_eq!(f.agg_ops(), f.aggregated);
    }

    #[test]
    fn replace_table_preserves_counters_and_busy_chain() {
        let mut f = fpe(64, 64);
        for id in 0..10u64 {
            f.offer(id, Key::from_id(id % 3, 16), 1, AggOp::Sum);
        }
        let writes = f.fifo_writes;
        let agg = (f.aggregated, f.inserted, f.evicted);
        let ops = f.agg_ops();
        let lat = f.latency_cycles;
        let depth = f.fifo_depth();

        let sat = f.table().saturated;
        let mut spilled = Vec::new();
        f.replace_table(HashTable::with_memory(40, 16, 2), &mut spilled);
        assert_eq!(spilled.len(), 3, "residents drained, not dropped");
        assert_eq!(f.table().occupancy(), 0);
        assert_eq!(f.table().saturated, sat, "saturation count survives the swap");
        f.audit().unwrap();

        assert_eq!(f.fifo_writes, writes);
        assert_eq!((f.aggregated, f.inserted, f.evicted), agg);
        assert_eq!(f.agg_ops(), ops, "lifetime combine count survives the swap");
        assert_eq!(f.latency_cycles, lat);
        assert_eq!(f.fifo_depth(), depth, "busy chain untouched");
    }

    fn vfpe(pairs: usize, lanes: usize, fifo_cap: usize) -> Fpe {
        let table =
            HashTable::with_memory_lanes((pairs * (16 + lanes * 4)) as u64, 16, 2, lanes);
        Fpe::new(
            1,
            table,
            2,
            StageDelays::default(),
            EvictionPolicy::EvictOld,
            fifo_cap,
        )
    }

    #[test]
    fn lane_offer_timing_and_counters_match_scalar_at_w1() {
        let mut scalar = fpe(1, 64);
        let mut lane = vfpe(1, 1, 64);
        let mut sink = VectorEvictSink::new();
        for id in 0..30u64 {
            let k = Key::from_id(id % 5, 16);
            let s = scalar.offer(id * 3, k, 1, AggOp::Sum);
            let l = lane.offer_lanes(id * 3, k, &[1], AggOp::Sum, &mut sink);
            match (s, l) {
                (FpeOutcome::Kept, None) => {}
                (FpeOutcome::Forwarded { key, value, hash, ready }, Some(lready)) => {
                    assert_eq!(ready, lready);
                    let (lk, lh) = *sink.keys.last().unwrap();
                    assert_eq!((key, hash), (lk, lh));
                    assert_eq!(value, *sink.lanes.last().unwrap());
                }
                other => panic!("paths diverged: {other:?}"),
            }
        }
        assert_eq!(
            (scalar.aggregated, scalar.inserted, scalar.evicted),
            (lane.aggregated, lane.inserted, lane.evicted)
        );
        assert_eq!(scalar.fifo_writes, lane.fifo_writes);
        assert_eq!(scalar.fifo_full_events, lane.fifo_full_events);
        assert_eq!(scalar.latency_cycles, lane.latency_cycles);
        assert_eq!(scalar.agg_ops(), lane.agg_ops());
    }

    #[test]
    fn wide_engine_counts_w_combines_per_hit() {
        let mut f = vfpe(64, 8, 64);
        let mut sink = VectorEvictSink::new();
        let k = Key::from_id(1, 16);
        let lanes = [1i64; 8];
        f.offer_lanes(0, k, &lanes, AggOp::Sum, &mut sink);
        f.offer_lanes(10, k, &lanes, AggOp::Sum, &mut sink);
        f.offer_lanes(20, k, &lanes, AggOp::Sum, &mut sink);
        assert_eq!(f.aggregated, 2);
        assert_eq!(f.agg_ops(), 16, "2 hits x 8 lanes");
        // Columnar flush streams the wider slots.
        let mut keys = Vec::new();
        let mut vals = Vec::new();
        let cycles = f.flush_lanes_into(&mut keys, &mut vals);
        assert_eq!(keys.len(), 1);
        assert_eq!(vals, vec![3i64; 8]);
        // 1 slot * (16 + 32) B = 48 B = 3 beats.
        assert_eq!(cycles, 3);
    }
}
