//! Front-end processing engine (FPE, §4.2.4, Fig. 6–7).
//!
//! Each FPE serves one key-length group with an SRAM (BRAM) hash
//! table: hash → bucket lookup → aggregate on hit, insert on free
//! slot, evict-and-forward on a full bucket.  BRAM reads/writes are
//! single-cycle, so the pipelined engine accepts one pair every
//! `interval` cycles; the stage *latencies* (Table 3) ride on top.
//!
//! Timing is transaction-level: each offered pair carries its arrival
//! cycle; the engine tracks its input-FIFO occupancy by retiring
//! service-completion timestamps, which yields exactly the Table 2
//! counters (writes / full events).

use crate::protocol::{AggOp, Key, Value};
use crate::sim::Cycles;
use crate::switch::aggregate::AggregationUnit;
use crate::switch::config::{EvictionPolicy, StageDelays};
use crate::switch::hash_table::{HashTable, Probe, VALUE_BYTES};

/// What happened to an offered pair.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FpeOutcome {
    /// Aggregated or inserted; nothing leaves the engine.
    Kept,
    /// A pair leaves towards the BPE / output at `ready` (Fig. 7),
    /// carrying its hash-unit output so the BPE need not re-hash.
    Forwarded {
        key: Key,
        value: Value,
        hash: u32,
        ready: Cycles,
    },
}

#[derive(Clone, Debug)]
pub struct Fpe {
    pub group: usize,
    table: HashTable,
    agg: AggregationUnit,
    interval: Cycles,
    delays: StageDelays,
    eviction: EvictionPolicy,
    fifo_cap: usize,
    busy_until: Cycles,
    // Table 2 counters.
    pub fifo_writes: u64,
    pub fifo_full_events: u64,
    // Outcome counters.
    pub aggregated: u64,
    pub inserted: u64,
    pub evicted: u64,
    /// Total pair-latency cycles (for Table 3 averages).
    pub latency_cycles: u64,
}

impl Fpe {
    pub fn new(
        group: usize,
        table: HashTable,
        interval: Cycles,
        delays: StageDelays,
        eviction: EvictionPolicy,
        fifo_cap: usize,
    ) -> Self {
        Self {
            group,
            table,
            agg: AggregationUnit::new(),
            interval,
            delays,
            eviction,
            fifo_cap,
            busy_until: 0,
            fifo_writes: 0,
            fifo_full_events: 0,
            aggregated: 0,
            inserted: 0,
            evicted: 0,
            latency_cycles: 0,
        }
    }

    pub fn table(&self) -> &HashTable {
        &self.table
    }

    /// FIFO occupancy as seen by an arrival at cycle `at`.
    ///
    /// Completions within one busy period are spaced exactly
    /// `interval` cycles (accepts serialize on `busy_until`), so the
    /// occupancy is the closed form
    /// `ceil((busy_until - at) / interval)` — no per-pair queue needed.
    pub fn fifo_depth_at(&self, at: Cycles) -> usize {
        if self.busy_until <= at {
            0
        } else {
            (self.busy_until - at).div_ceil(self.interval) as usize
        }
    }

    pub fn fifo_depth(&self) -> usize {
        self.fifo_depth_at(self.busy_until.saturating_sub(1))
    }

    /// Offer one pair arriving (from the crossbar) at cycle `arrive`.
    pub fn offer(&mut self, arrive: Cycles, key: Key, value: Value, op: AggOp) -> FpeOutcome {
        // Backpressure: if the FIFO is full the producer stalls until
        // the oldest pair retires (counted as a full event, Table 2).
        let mut effective_arrive = arrive;
        let depth = self.fifo_depth_at(arrive);
        if depth >= self.fifo_cap {
            self.fifo_full_events += 1;
            // The oldest queued pair completes at
            // busy_until - (depth - 1) * interval.
            let oldest_done = self.busy_until - (depth as Cycles - 1) * self.interval;
            effective_arrive = effective_arrive.max(oldest_done);
        }
        self.fifo_writes += 1;

        let start = effective_arrive.max(self.busy_until);
        self.busy_until = start + self.interval;

        // Functional behaviour.  The hash unit runs once here; its
        // output is the table tag and rides along on eviction.
        let evict_old = self.eviction == EvictionPolicy::EvictOld;
        let hash = self.table.hash_of(&key);
        let outcome = match self.table.offer_hashed(hash, key, value, op, evict_old) {
            Probe::Aggregated => {
                self.aggregated += 1;
                // Hash + aggregate latency (Table 3 rows 3-4).
                self.latency_cycles += self.delays.fpe_hash + self.delays.fpe_aggregate;
                FpeOutcome::Kept
            }
            Probe::Inserted => {
                self.inserted += 1;
                self.latency_cycles += self.delays.fpe_hash + self.delays.fpe_aggregate;
                FpeOutcome::Kept
            }
            Probe::Evicted(k, v, h) => {
                self.evicted += 1;
                let lat =
                    self.delays.fpe_hash + self.delays.fpe_aggregate + self.delays.fpe_forward;
                self.latency_cycles += lat;
                FpeOutcome::Forwarded {
                    key: k,
                    value: v,
                    hash: h,
                    ready: start + lat,
                }
            }
        };
        outcome
    }

    /// Flush: drain the SRAM table into `out` (appending, so one
    /// scratch buffer serves every engine); returns the stream-out
    /// cycle cost (one 16 B beat per cycle out of BRAM).
    pub fn flush_into(&mut self, out: &mut Vec<(Key, Value)>) -> Cycles {
        let before = out.len();
        self.table.drain_into(out);
        let bytes = ((out.len() - before) * (self.table.slot_key_width() + VALUE_BYTES)) as u64;
        crate::sim::clock::stream_cycles(bytes)
    }

    /// [`Self::flush_into`] into a fresh vector.
    pub fn flush(&mut self) -> (Vec<(Key, Value)>, Cycles) {
        let mut pairs = Vec::with_capacity(self.table.occupancy());
        let cycles = self.flush_into(&mut pairs);
        (pairs, cycles)
    }

    pub fn full_ratio(&self) -> f64 {
        if self.fifo_writes == 0 {
            0.0
        } else {
            self.fifo_full_events as f64 / self.fifo_writes as f64
        }
    }

    pub fn agg_ops(&self) -> u64 {
        self.agg.ops_executed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::switch::config::StageDelays;

    fn fpe(pairs: usize, fifo_cap: usize) -> Fpe {
        let table = HashTable::with_memory((pairs * 20) as u64, 16, 2);
        Fpe::new(
            1,
            table,
            2,
            StageDelays::default(),
            EvictionPolicy::EvictOld,
            fifo_cap,
        )
    }

    #[test]
    fn hit_insert_evict_counters() {
        let mut f = fpe(2, 64);
        let k1 = Key::from_id(1, 16);
        assert_eq!(f.offer(0, k1, 5, AggOp::Sum), FpeOutcome::Kept);
        assert_eq!(f.offer(10, k1, 6, AggOp::Sum), FpeOutcome::Kept);
        assert_eq!(f.inserted, 1);
        assert_eq!(f.aggregated, 1);
        assert_eq!(f.table().get(&k1), Some(11));
    }

    #[test]
    fn eviction_forward_has_pipeline_latency() {
        // 1 bucket x 2 slots => third distinct key evicts a resident.
        let mut f = fpe(1, 64);
        let k1 = Key::from_id(1, 16);
        let k2 = Key::from_id(2, 16);
        let k3 = Key::from_id(3, 16);
        f.offer(0, k1, 5, AggOp::Sum);
        f.offer(50, k2, 6, AggOp::Sum);
        match f.offer(100, k3, 7, AggOp::Sum) {
            FpeOutcome::Forwarded {
                key, value, ready, ..
            } => {
                // Round-robin cursor starts at slot 0 -> k1 evicted.
                assert_eq!(key, k1);
                assert_eq!(value, 5);
                // start=100, +10+18+5.
                assert_eq!(ready, 133);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn fifo_fills_under_burst() {
        // interval 2, fifo_cap 4: 20 pairs arriving at the same cycle
        // must generate full events.
        let mut f = fpe(1024, 4);
        for id in 0..20u64 {
            f.offer(0, Key::from_id(id, 16), 1, AggOp::Sum);
        }
        assert_eq!(f.fifo_writes, 20);
        assert!(f.fifo_full_events > 0, "burst should overflow FIFO");
        assert!(f.full_ratio() > 0.0);
    }

    #[test]
    fn paced_arrivals_never_fill_fifo() {
        // One pair every 4 cycles into a 2-cycle engine: no pressure.
        let mut f = fpe(1024, 4);
        for id in 0..100u64 {
            f.offer(id * 4, Key::from_id(id, 16), 1, AggOp::Sum);
        }
        assert_eq!(f.fifo_full_events, 0);
    }

    #[test]
    fn flush_drains_and_costs_stream_cycles() {
        let mut f = fpe(64, 64);
        for id in 0..10u64 {
            f.offer(id, Key::from_id(id, 16), 1, AggOp::Sum);
        }
        let (pairs, cycles) = f.flush();
        assert_eq!(pairs.len(), 10);
        // 10 slots * 20B = 200 B = 13 beats.
        assert_eq!(cycles, 13);
        assert_eq!(f.table().occupancy(), 0);
    }
}
