//! Per-tenant switch state: one aggregation tree's engine plus the
//! directory that owns every resident tree.
//!
//! Before this module existed, `SwitchAggSwitch` held a flat
//! `BTreeMap<TreeId, TreeEngine>` that `rebuild_engines` wiped and
//! re-split on every `configure()` call — admitting one tenant
//! destroyed every neighbor's FPE/BPE state.  `TenantDirectory` makes
//! tree residency incremental: tenants are admitted against an
//! explicit FPE/BPE memory ledger, evicted one at a time (their
//! resident pairs drained for software merge, never dropped), and
//! survive neighbor churn byte-for-byte.
//!
//! Admission is checked, not best-effort: a [`QuotaRequest`] that
//! cannot be satisfied is rejected with a typed [`AdmissionError`]
//! before any engine state is touched.  Under pressure, idle tenants'
//! slots can be *reclaimed* — their tables shrunk to the minimum
//! viable share, the displaced pairs handed back to the caller for
//! software aggregation — so an arriving job is admitted at the cost
//! of an idle neighbor's reduction ratio, never its correctness.

use crate::protocol::vector::{max_vec_payload, vec_fixed_len};
use crate::protocol::{
    AggOp, Key, KvPair, TreeConfig, TreeId, Value, VectorBatch, AGG_FIXED_LEN, HEADER_OVERHEAD,
};
use crate::sim::clock::Cycles;
use crate::switch::bpe::{Bpe, BpeOutcome};
use crate::switch::config::{EvictionPolicy, SwitchConfig};
use crate::switch::crossbar::Crossbar;
use crate::switch::fpe::{Fpe, FpeOutcome};
use crate::switch::hash_table::{HashTable, VectorEvictSink};
use crate::switch::parallel::{merge_by_seq, run_workers, JobPair, WorkerGroup};
use crate::switch::payload_analyzer::{GroupMap, PayloadAnalyzer};
use crate::switch::scheduler::{SchedPolicy, Scheduler};
use crate::switch::switch_sim::{IngestSink, SwitchStats, VectorSink};
use crate::util::codec::{self, SnapCursor, SnapshotError};
use std::collections::BTreeMap;

/// Input pacing: cycles per byte on a 10 Gbps port at 200 MHz
/// (1.25 GB/s ÷ 200 Mcycle/s = 6.25 B/cycle = 4/25 cycle/B).
const PACE_NUM: u64 = 4;
const PACE_DEN: u64 = 25;

/// One aggregation tree's slice of the data plane.
pub(crate) struct TreeEngine {
    op: AggOp,
    children: u16,
    eot_seen: u16,
    /// Value lanes per key (W); 1 = the scalar data plane.
    lanes: usize,
    analyzer: PayloadAnalyzer,
    crossbar: Crossbar,
    scheduler: Scheduler,
    pub(crate) fpes: Vec<Fpe>,
    pub(crate) bpe: Option<Bpe>,
    /// Byte-pacing accumulator for input arrivals.
    bytes_arrived: u64,
    /// PE-input FIFO capacity (shared by every FPE and the BPE) — the
    /// denominator of the backpressure-credit headroom.
    fifo_cap: usize,
    /// Reused FPE-eviction scratch for the vector path (one evictee).
    evict_scratch: VectorEvictSink,
    /// Reused BPE-overflow scratch for the vector path (one pair).
    overflow_scratch: VectorEvictSink,
    pub(crate) stats: SwitchStats,
}

impl TreeEngine {
    pub(crate) fn new(
        cfg: &SwitchConfig,
        op: AggOp,
        children: u16,
        fpe_share: u64,
        bpe_share: Option<u64>,
        lanes: usize,
    ) -> Self {
        let fpe_mem_each = fpe_share / cfg.n_groups as u64;
        let map = GroupMap::new(cfg.n_groups, cfg.key_base);
        let fpes = (0..cfg.n_groups)
            .map(|g| {
                let table = HashTable::with_memory_lanes(
                    fpe_mem_each,
                    cfg.group_width(g),
                    cfg.fpe_slots_per_bucket,
                    lanes,
                );
                Fpe::new(
                    g,
                    table,
                    cfg.fpe_interval,
                    cfg.delays,
                    cfg.eviction,
                    cfg.fifo_cap,
                )
            })
            .collect();
        let bpe = bpe_share.map(|m| Bpe::for_tree_lanes(cfg, m, lanes));
        Self {
            op,
            children,
            eot_seen: 0,
            lanes,
            analyzer: PayloadAnalyzer::new(map),
            crossbar: Crossbar::new(cfg.n_groups, cfg.delays.crossbar),
            scheduler: Scheduler::new(cfg.n_groups, SchedPolicy::RoundRobin),
            fpes,
            bpe,
            bytes_arrived: 0,
            fifo_cap: cfg.fifo_cap,
            evict_scratch: VectorEvictSink::new(),
            overflow_scratch: VectorEvictSink::new(),
            stats: SwitchStats::default(),
        }
    }

    /// Current arrival cycle implied by bytes received at line rate.
    /// Each child feeds its own 10 Gbps port through its own payload
    /// analyzer (§5 instantiates one PA per port), so the aggregate
    /// ingress rate scales with the child count: pairs from k children
    /// land on the shared FPEs k× as fast as a single stream would.
    fn arrival_cycle(&self) -> Cycles {
        let ports = (self.children as u64).max(1);
        self.bytes_arrived * PACE_NUM / (PACE_DEN * ports)
    }

    /// Packet-header arrival accounting shared by the serial, sharded,
    /// and vector front ends — with [`Self::account_pair`], the single
    /// source of the input-pacing rule, so the paths cannot drift.
    /// For scalar trees (`lanes == 1`) the fixed length is exactly
    /// [`AGG_FIXED_LEN`]; W-lane trees carry the 2-byte lane count.
    fn account_packet_header(&mut self) {
        let fixed = (HEADER_OVERHEAD + vec_fixed_len(self.lanes)) as u64;
        debug_assert!(self.lanes > 1 || fixed == (HEADER_OVERHEAD + AGG_FIXED_LEN) as u64);
        self.stats.packets_in += 1;
        self.stats.bytes_in += fixed;
        self.bytes_arrived += fixed;
    }

    /// Per-pair arrival accounting (bytes, pacing, payload analyzer);
    /// returns the pair's `(group, arrival cycle)`.
    fn account_pair(&mut self, p: &KvPair, header_delay: Cycles) -> (usize, Cycles) {
        let el = p.encoded_len() as u64;
        self.stats.bytes_in += el;
        self.bytes_arrived += el;
        self.stats.pairs_in += 1;
        let arrive = self.arrival_cycle() + header_delay;
        let g = self.analyzer.classify(p);
        (g, arrive)
    }

    /// Ingest one packet's worth of pairs.  This is the core ingest
    /// path: the packet need not be materialized — stream entry points
    /// pass MTU-sized chunks of the caller's slice directly.
    pub(crate) fn ingest_pairs(
        &mut self,
        pairs: &[KvPair],
        eot: bool,
        header_delay: Cycles,
        out: &mut IngestSink,
    ) {
        assert_eq!(
            self.lanes, 1,
            "scalar ingest on a tree configured for {}-lane vector payloads",
            self.lanes
        );
        self.account_packet_header();

        for p in pairs {
            let (g, arrive) = self.account_pair(p, header_delay);
            let deliver = self.crossbar.route(arrive, g);
            match self.fpes[g].offer(deliver, p.key, p.value, self.op) {
                FpeOutcome::Kept => {}
                FpeOutcome::Forwarded {
                    key,
                    value,
                    hash,
                    ready,
                } => {
                    self.forward_evicted(g, key, value, hash, ready, out);
                }
            }
        }

        if eot {
            self.eot_seen += 1;
            if self.eot_seen >= self.children {
                self.flush_into(out);
            }
        }
        self.roll_stats();
    }

    /// Route an FPE-evicted pair: to the BPE if the hierarchy is on,
    /// straight downstream otherwise (fig9 "S-" single-level rows).
    fn forward_evicted(
        &mut self,
        group: usize,
        key: Key,
        value: Value,
        hash: u32,
        ready: Cycles,
        out: &mut IngestSink,
    ) {
        match &mut self.bpe {
            Some(bpe) => {
                // The scheduler grants this FPE's forward queue; the
                // event-driven model presents evictions one at a time,
                // so the queue-depth vector would be a singleton.
                let granted = self.scheduler.grant_single(group);
                debug_assert_eq!(granted, group);
                match bpe.offer_hashed(ready, group, key, value, hash, self.op) {
                    BpeOutcome::Kept => {}
                    BpeOutcome::Overflow { key, value, .. } => {
                        self.emit_pair(KvPair::new(key, value), out);
                    }
                }
            }
            None => self.emit_pair(KvPair::new(key, value), out),
        }
    }

    fn emit_pair(&mut self, p: KvPair, out: &mut IngestSink) {
        self.stats.pairs_out_stream += 1;
        self.stats.bytes_out += p.encoded_len() as u64;
        out.forwarded.push(p);
    }

    /// Flush every engine (EoT from all children, §4.2.2): residents
    /// stream downstream; Table 3's BPE-Flush dominates the cost.
    fn flush_into(&mut self, out: &mut IngestSink) {
        out.flushes += 1;
        let start = out.flushed.len();
        let mut flush_cycles: Cycles = 0;
        for f in &mut self.fpes {
            out.scratch.clear();
            flush_cycles += f.flush_into(&mut out.scratch);
            out.flushed
                .extend(out.scratch.iter().map(|&(k, v)| KvPair::new(k, v)));
        }
        if let Some(bpe) = &mut self.bpe {
            out.scratch.clear();
            flush_cycles += bpe.flush_into(&mut out.scratch);
            out.flushed
                .extend(out.scratch.iter().map(|&(k, v)| KvPair::new(k, v)));
        }
        self.stats.flush_cycles += flush_cycles;
        let flushed_now = &out.flushed[start..];
        self.stats.pairs_out_flush += flushed_now.len() as u64;
        self.stats.bytes_out += flushed_now.iter().map(|p| p.encoded_len() as u64).sum::<u64>();
        self.eot_seen = 0;
    }

    /// Fold engine counters into the per-tree stats snapshot.
    fn roll_stats(&mut self) {
        let fpe_aggregated = self.fpes.iter().map(|f| f.aggregated).sum();
        let fpe_inserted = self.fpes.iter().map(|f| f.inserted).sum();
        let fpe_evicted = self.fpes.iter().map(|f| f.evicted).sum();
        let mut fifo_writes: u64 = self.fpes.iter().map(|f| f.fifo_writes).sum();
        let mut fifo_full: u64 = self.fpes.iter().map(|f| f.fifo_full_events).sum();
        if let Some(b) = &self.bpe {
            self.stats.bpe_aggregated = b.aggregated;
            self.stats.bpe_inserted = b.inserted;
            self.stats.bpe_overflowed = b.overflowed;
            fifo_writes += b.fifo_writes;
            fifo_full += b.fifo_full_events;
        }
        self.stats.fpe_aggregated = fpe_aggregated;
        self.stats.fpe_inserted = fpe_inserted;
        self.stats.fpe_evicted = fpe_evicted;
        self.stats.fifo_writes = fifo_writes;
        self.stats.fifo_full_events = fifo_full;
        let mut fifo_peak: u64 = self.fpes.iter().map(|f| f.fifo_peak).max().unwrap_or(0);
        if let Some(b) = &self.bpe {
            fifo_peak = fifo_peak.max(b.fifo_peak);
        }
        self.stats.fifo_max_occupancy = fifo_peak;
        self.stats.makespan_cycles = self.arrival_cycle();
        let mut saturated: u64 = self.fpes.iter().map(|f| f.table().saturated).sum();
        if let Some(b) = &self.bpe {
            saturated += b.saturated_ops();
        }
        self.stats.saturated_combines = saturated;
    }

    /// Verify every engine memory region's audit digest (FPE SRAM
    /// tables, then BPE DRAM regions).  `Err` carries the failing
    /// stage/region and the `(expected, computed)` digests.
    pub fn audit(&self) -> Result<(), (String, u64, u64)> {
        for f in &self.fpes {
            if let Err((expected, computed)) = f.audit() {
                return Err((format!("fpe group {}", f.group), expected, computed));
            }
        }
        if let Some(b) = &self.bpe {
            if let Err((g, expected, computed)) = b.audit() {
                return Err((format!("bpe region {g}"), expected, computed));
            }
        }
        Ok(())
    }

    /// Inject one seeded SRAM/DRAM bit flip into some resident slot,
    /// bypassing the audit digests (the single-event-upset model).
    /// Tries the seed-selected FPE first, then the rest, then the BPE;
    /// `false` if no engine holds a resident pair.
    pub fn poison_sram(&mut self, seed: u64) -> bool {
        let n = self.fpes.len();
        for i in 0..n {
            let g = (seed as usize + i) % n;
            if self.fpes[g].poison_bit(seed) {
                return true;
            }
        }
        if let Some(b) = &mut self.bpe {
            return b.poison_bit(seed);
        }
        false
    }

    /// Instantaneous PE-input queue state as seen by the next arrival:
    /// `(deepest FIFO, capacity)` — the backpressure signal behind
    /// [`CreditPolicy::Backpressure`]'s credit advertisement.
    pub(crate) fn input_queue(&self) -> (usize, usize) {
        let at = self.arrival_cycle();
        let mut depth = self
            .fpes
            .iter()
            .map(|f| f.fifo_depth_at(at))
            .max()
            .unwrap_or(0);
        if let Some(b) = &self.bpe {
            depth = depth.max(b.fifo_depth_at(at));
        }
        (depth, self.fifo_cap)
    }

    /// Ingest one packet's worth of W-lane vector pairs — the columnar
    /// counterpart of [`Self::ingest_pairs`], sharing the pacing,
    /// analyzer, crossbar, FPE/BPE timing and stats machinery; at
    /// `W = 1` it is byte-identical to the scalar path.  Always runs
    /// on the serial reference engine (the sharded engine's ownership
    /// seams are unchanged by lane width; vector sharding can reuse
    /// them later).
    pub(crate) fn ingest_vector_range(
        &mut self,
        batch: &VectorBatch,
        range: std::ops::Range<usize>,
        eot: bool,
        header_delay: Cycles,
        out: &mut VectorSink,
    ) {
        assert_eq!(
            batch.lanes(),
            self.lanes,
            "batch lane width does not match the tree's configured width"
        );
        let w = self.lanes;
        self.account_packet_header();

        for i in range {
            let key = batch.key(i);
            let lanes = batch.lane_slice(i);
            let el = batch.encoded_len_pair(i);
            self.stats.bytes_in += el as u64;
            self.bytes_arrived += el as u64;
            self.stats.pairs_in += 1;
            let arrive = self.arrival_cycle() + header_delay;
            let g = self.analyzer.classify_parts(key.len(), el);
            let deliver = self.crossbar.route(arrive, g);
            self.evict_scratch.clear();
            let forwarded =
                self.fpes[g].offer_lanes(deliver, key, lanes, self.op, &mut self.evict_scratch);
            if let Some(ready) = forwarded {
                let (ek, ehash) = self.evict_scratch.keys[0];
                match &mut self.bpe {
                    Some(bpe) => {
                        let granted = self.scheduler.grant_single(g);
                        debug_assert_eq!(granted, g);
                        self.overflow_scratch.clear();
                        let overflow = bpe.offer_lanes_hashed(
                            ready,
                            g,
                            (ek, ehash),
                            self.evict_scratch.lane_slice(0, w),
                            self.op,
                            &mut self.overflow_scratch,
                        );
                        if overflow.is_some() {
                            let (ok, _) = self.overflow_scratch.keys[0];
                            let olanes = self.overflow_scratch.lane_slice(0, w);
                            self.stats.pairs_out_stream += 1;
                            self.stats.bytes_out += crate::protocol::vector::encoded_vec_len(
                                ok.len(),
                                w,
                                crate::protocol::vector::lane_value_width(olanes),
                            ) as u64;
                            out.forwarded.push(ok, olanes);
                        }
                    }
                    None => {
                        let elanes = self.evict_scratch.lane_slice(0, w);
                        self.stats.pairs_out_stream += 1;
                        self.stats.bytes_out += crate::protocol::vector::encoded_vec_len(
                            ek.len(),
                            w,
                            crate::protocol::vector::lane_value_width(elanes),
                        ) as u64;
                        out.forwarded.push(ek, elanes);
                    }
                }
            }
        }

        if eot {
            self.eot_seen += 1;
            if self.eot_seen >= self.children {
                self.flush_vector_into(out);
            }
        }
        self.roll_stats();
    }

    /// End-of-tree flush of a W-lane tree: every engine drains
    /// columnar into the sink; byte/pair accounting mirrors
    /// [`Self::flush_into`].
    fn flush_vector_into(&mut self, out: &mut VectorSink) {
        let w = self.lanes;
        out.flushes += 1;
        let start = out.flushed.len();
        let mut flush_cycles: Cycles = 0;
        for f in &mut self.fpes {
            out.scratch_keys.clear();
            out.scratch_vals.clear();
            flush_cycles += f.flush_lanes_into(&mut out.scratch_keys, &mut out.scratch_vals);
            for (j, &k) in out.scratch_keys.iter().enumerate() {
                out.flushed.push(k, &out.scratch_vals[j * w..(j + 1) * w]);
            }
        }
        if let Some(bpe) = &mut self.bpe {
            out.scratch_keys.clear();
            out.scratch_vals.clear();
            flush_cycles += bpe.flush_lanes_into(&mut out.scratch_keys, &mut out.scratch_vals);
            for (j, &k) in out.scratch_keys.iter().enumerate() {
                out.flushed.push(k, &out.scratch_vals[j * w..(j + 1) * w]);
            }
        }
        self.stats.flush_cycles += flush_cycles;
        let flushed_now = out.flushed.len() - start;
        self.stats.pairs_out_flush += flushed_now as u64;
        self.stats.bytes_out += (start..out.flushed.len())
            .map(|i| out.flushed.encoded_len_pair(i) as u64)
            .sum::<u64>();
        self.eot_seen = 0;
    }

    /// Recovery fallback: run the all-EoTs flush now, regardless of how
    /// many EoT signals actually arrived.  The framework's corruption
    /// driver calls this when a flipped flags byte destroyed an
    /// end-of-transmission bit that no retransmission will redeliver
    /// (the corrupted copy was admitted, so the seq is acked).
    pub(crate) fn force_flush(&mut self, out: &mut IngestSink) {
        self.flush_into(out);
        self.roll_stats();
    }

    /// W-lane counterpart of [`Self::force_flush`].
    pub(crate) fn force_flush_vector(&mut self, out: &mut VectorSink) {
        self.flush_vector_into(out);
        self.roll_stats();
    }

    /// Account trailing per-packet header overhead on the output side:
    /// streamed-out pairs are packed into MTU-sized packets downstream
    /// (W-lane trees pack into per-W packet budgets; at `W = 1` this
    /// is exactly the scalar packetization).
    pub(crate) fn finalize_output_bytes(&mut self) {
        let payload = self.stats.bytes_out;
        let pkts = payload.div_ceil(max_vec_payload(self.lanes) as u64).max(
            (self.stats.pairs_out_stream + self.stats.pairs_out_flush > 0) as u64,
        );
        self.stats.bytes_out = payload + pkts * (HEADER_OVERHEAD + vec_fixed_len(self.lanes)) as u64;
    }

    /// Whether this chunk sequence would trigger an end-of-tree flush
    /// anywhere but at the very last chunk.  The sharded engine defers
    /// its single flush to the merge stage; a mid-stream flush resets
    /// table state between pairs and must take the serial path.
    pub(crate) fn flush_splits_stream(&self, chunks: &[(&[KvPair], bool)]) -> bool {
        let mut eot_seen = self.eot_seen;
        for (i, &(_, eot)) in chunks.iter().enumerate() {
            if eot {
                eot_seen += 1;
                if eot_seen >= self.children {
                    if i + 1 != chunks.len() {
                        return true;
                    }
                    eot_seen = 0;
                }
            }
        }
        false
    }

    /// Sharded ingest of a whole chunk sequence (see `switch::parallel`
    /// for why this is byte-identical to calling
    /// [`Self::ingest_pairs`] per chunk).
    pub(crate) fn ingest_chunks_sharded(
        &mut self,
        chunks: &[(&[KvPair], bool)],
        header_delay: Cycles,
        shards: usize,
        out: &mut IngestSink,
    ) {
        let n_groups = self.fpes.len();
        // Front end (serial): byte pacing + analyzer accounting; every
        // pair is stamped with its global sequence number and arrival
        // cycle and binned by group.
        let mut jobs: Vec<Vec<JobPair>> = (0..n_groups).map(|_| Vec::new()).collect();
        let mut seq: u64 = 0;
        let mut eots: u32 = 0;
        for &(pairs, eot) in chunks {
            self.account_packet_header();
            for p in pairs {
                let (g, arrive) = self.account_pair(p, header_delay);
                jobs[g].push(JobPair {
                    seq,
                    arrive,
                    pair: *p,
                });
                seq += 1;
            }
            if eot {
                eots += 1;
            }
        }
        // Distribute disjoint {FPE, BPE region, crossbar output} shards
        // round-robin across workers (spreads the skewed group weights
        // better than contiguous ranges).
        let op = self.op;
        let evict_old = self
            .bpe
            .as_ref()
            .map(|b| b.eviction() == EvictionPolicy::EvictOld)
            .unwrap_or(false);
        let mut regions: Vec<Option<&mut HashTable>> = match self.bpe.as_mut() {
            Some(b) => b.regions_mut().iter_mut().map(Some).collect(),
            None => (0..n_groups).map(|_| None).collect(),
        };
        let mut per_worker: Vec<Vec<WorkerGroup<'_>>> =
            (0..shards).map(|_| Vec::new()).collect();
        for ((g, fpe), job) in self.fpes.iter_mut().enumerate().zip(jobs) {
            per_worker[g % shards].push(WorkerGroup {
                group: g,
                job,
                fpe,
                region: regions[g].take(),
                port: self.crossbar.port_view(g),
                op,
                evict_old,
            });
        }
        let mut outputs = run_workers(per_worker);
        outputs.sort_by_key(|o| o.group);
        // Merge (serial, deterministic): fold the per-output crossbar
        // views and BPE probe counts back in, replay the shared BPE
        // timing in global eviction order, then emit downstream pairs
        // in the serial path's order.
        for o in &outputs {
            self.crossbar.absorb(o.group, o.port);
            if let Some(b) = self.bpe.as_mut() {
                b.absorb_probe_counts(o.bpe_aggregated, o.bpe_inserted, o.bpe_overflowed);
            }
        }
        let evict_streams: Vec<&[(u64, (usize, Cycles))]> =
            outputs.iter().map(|o| o.evicts.as_slice()).collect();
        let merged_evicts = merge_by_seq(&evict_streams);
        if let Some(b) = self.bpe.as_mut() {
            for &(_, (group, ready)) in &merged_evicts {
                let granted = self.scheduler.grant_single(group);
                debug_assert_eq!(granted, group);
                b.replay_timing(ready);
            }
        }
        let emission_streams: Vec<&[(u64, KvPair)]> =
            outputs.iter().map(|o| o.emissions.as_slice()).collect();
        let merged_emissions = merge_by_seq(&emission_streams);
        for (_, pair) in merged_emissions {
            self.emit_pair(pair, out);
        }
        // End-of-tree flushes — by the `flush_splits_stream`
        // precondition, at most one fires, and only at the stream end.
        for _ in 0..eots {
            self.eot_seen += 1;
            if self.eot_seen >= self.children {
                self.flush_into(out);
            }
        }
        self.roll_stats();
    }
}

impl TreeEngine {
    /// Resident pairs currently held in FPE tables plus BPE regions.
    pub(crate) fn resident_pairs(&self) -> usize {
        self.fpes.iter().map(|f| f.table().occupancy()).sum::<usize>()
            + self.bpe.as_ref().map_or(0, |b| b.occupancy_pairs())
    }

    /// Rebuild this engine's hash tables at a new memory share,
    /// draining every resident pair into `out` for software merge.
    /// Counters, FIFO timing, and DRAM state are preserved — only the
    /// tables are replaced — so a resized tenant keeps its cumulative
    /// [`SwitchStats`] and busy horizon.  Scalar-only: W-lane tenants
    /// are evict-or-keep, never elastically resized.
    pub(crate) fn resize_to(
        &mut self,
        cfg: &SwitchConfig,
        fpe_share: u64,
        bpe_share: Option<u64>,
        out: &mut Vec<KvPair>,
    ) {
        assert_eq!(self.lanes, 1, "elastic resize is scalar-only");
        let mut scratch: Vec<(Key, Value)> = Vec::new();
        let each = fpe_share / cfg.n_groups as u64;
        for (g, f) in self.fpes.iter_mut().enumerate() {
            let table = HashTable::with_memory_lanes(
                each,
                cfg.group_width(g),
                cfg.fpe_slots_per_bucket,
                1,
            );
            f.replace_table(table, &mut scratch);
        }
        if let (Some(b), Some(share)) = (self.bpe.as_mut(), bpe_share) {
            b.rebuild_regions(cfg, share, 1, &mut scratch);
        }
        out.extend(scratch.iter().map(|&(k, v)| KvPair::new(k, v)));
    }

    /// Drain every resident scalar pair (eviction path): in-flight
    /// state is handed back for software merge, never silently
    /// dropped.  The stream-out cycle cost is ignored — eviction is a
    /// management-plane action, not data-plane work.
    pub(crate) fn drain_residents(&mut self, out: &mut Vec<KvPair>) {
        let mut scratch: Vec<(Key, Value)> = Vec::new();
        for f in &mut self.fpes {
            f.flush_into(&mut scratch);
        }
        if let Some(b) = &mut self.bpe {
            b.flush_into(&mut scratch);
        }
        out.extend(scratch.iter().map(|&(k, v)| KvPair::new(k, v)));
    }

    /// W-lane twin of [`Self::drain_residents`].
    pub(crate) fn drain_residents_vector(&mut self, out: &mut VectorBatch) {
        let w = self.lanes;
        let mut keys: Vec<Key> = Vec::new();
        let mut vals: Vec<Value> = Vec::new();
        for f in &mut self.fpes {
            keys.clear();
            vals.clear();
            f.flush_lanes_into(&mut keys, &mut vals);
            for (j, &k) in keys.iter().enumerate() {
                out.push(k, &vals[j * w..(j + 1) * w]);
            }
        }
        if let Some(b) = &mut self.bpe {
            keys.clear();
            vals.clear();
            b.flush_lanes_into(&mut keys, &mut vals);
            for (j, &k) in keys.iter().enumerate() {
                out.push(k, &vals[j * w..(j + 1) * w]);
            }
        }
    }
}

impl TreeEngine {
    /// Serialize the engine-core state (pacing, EoT quorum, analyzer,
    /// crossbar, scheduler, cumulative stats) — everything *except* the
    /// FPE tables and BPE regions, which are separate snapshot sections
    /// so incremental checkpoints can ship only dirtied memory.  Leads
    /// with the geometry the restore target must match.
    pub(crate) fn snapshot_write_core(&self, out: &mut Vec<u8>) {
        codec::put_u32(out, self.lanes as u32);
        codec::put_u32(out, self.fpes.len() as u32);
        codec::put_u8(out, self.bpe.is_some() as u8);
        codec::put_u16(out, self.eot_seen);
        codec::put_u64(out, self.bytes_arrived);
        self.analyzer.snapshot_write(out);
        self.crossbar.snapshot_write(out);
        self.scheduler.snapshot_write(out);
        self.stats.snapshot_write(out);
    }

    /// Restore state written by [`Self::snapshot_write_core`] in place.
    /// The target engine must have been built from the same
    /// [`SwitchConfig`]/[`TreeConfig`] — geometry mismatches are typed
    /// errors, never silent reinterpretation.
    pub(crate) fn snapshot_read_core(
        &mut self,
        cur: &mut SnapCursor<'_>,
    ) -> Result<(), SnapshotError> {
        if cur.u32()? as usize != self.lanes {
            return Err(SnapshotError::Geometry("value lane width"));
        }
        if cur.u32()? as usize != self.fpes.len() {
            return Err(SnapshotError::Geometry("FPE group count"));
        }
        if (cur.u8()? != 0) != self.bpe.is_some() {
            return Err(SnapshotError::Geometry("BPE presence"));
        }
        let eot_seen = cur.u16()?;
        if eot_seen >= self.children.max(1) {
            return Err(SnapshotError::Invalid("EoT count at or beyond fan-in"));
        }
        self.eot_seen = eot_seen;
        self.bytes_arrived = cur.u64()?;
        self.analyzer.snapshot_read_into(cur)?;
        self.crossbar.snapshot_read_into(cur)?;
        self.scheduler.snapshot_read_into(cur)?;
        self.stats.snapshot_read_into(cur)?;
        Ok(())
    }

    pub(crate) fn n_fpe_groups(&self) -> usize {
        self.fpes.len()
    }

    pub(crate) fn n_bpe_regions(&self) -> usize {
        self.bpe.as_ref().map_or(0, |b| b.n_regions())
    }

    /// Serialize one FPE group's hash table (its own snapshot section).
    pub(crate) fn snapshot_write_fpe(&self, group: usize, out: &mut Vec<u8>) {
        self.fpes[group].snapshot_write(out);
    }

    pub(crate) fn snapshot_read_fpe(
        &mut self,
        group: usize,
        cur: &mut SnapCursor<'_>,
    ) -> Result<(), SnapshotError> {
        self.fpes[group].snapshot_read_into(cur)
    }

    /// Serialize the BPE's non-table state (DRAM timing, counters).
    /// Must only be called when [`Self::n_bpe_regions`] is nonzero.
    pub(crate) fn snapshot_write_bpe_meta(&self, out: &mut Vec<u8>) {
        self.bpe.as_ref().expect("no BPE").snapshot_write_meta(out);
    }

    pub(crate) fn snapshot_read_bpe_meta(
        &mut self,
        cur: &mut SnapCursor<'_>,
    ) -> Result<(), SnapshotError> {
        match &mut self.bpe {
            Some(b) => b.snapshot_read_meta(cur),
            None => Err(SnapshotError::Geometry("BPE presence")),
        }
    }

    /// Serialize one BPE DRAM region (its own snapshot section).
    pub(crate) fn snapshot_write_bpe_region(&self, group: usize, out: &mut Vec<u8>) {
        self.bpe
            .as_ref()
            .expect("no BPE")
            .snapshot_write_region(group, out);
    }

    pub(crate) fn snapshot_read_bpe_region(
        &mut self,
        group: usize,
        cur: &mut SnapCursor<'_>,
    ) -> Result<(), SnapshotError> {
        match &mut self.bpe {
            Some(b) => b.snapshot_read_region(group, cur),
            None => Err(SnapshotError::Geometry("BPE presence")),
        }
    }
}

// ---------------------------------------------------------------------------
// Quotas, admission, and the tenant directory
// ---------------------------------------------------------------------------

/// A tenant's requested slice of switch memory, in bytes.  `bpe_bytes`
/// is ignored on switches configured without a BPE.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuotaRequest {
    pub fpe_bytes: u64,
    pub bpe_bytes: u64,
}

impl QuotaRequest {
    /// An even 1/n split of the switch's total FPE/BPE memory.
    pub fn even_split(cfg: &SwitchConfig, n: u64) -> Self {
        let n = n.max(1);
        Self {
            fpe_bytes: cfg.fpe_total_mem / n,
            bpe_bytes: cfg.bpe_mem.unwrap_or(0) / n,
        }
    }

    /// The whole switch.
    pub fn full(cfg: &SwitchConfig) -> Self {
        Self::even_split(cfg, 1)
    }

    /// Clamp both stages up to the minimum viable scalar share so a
    /// tiny request is admitted at floor capacity instead of rejected
    /// as zero-capacity.
    pub fn at_least_floor(self, cfg: &SwitchConfig) -> Self {
        let min = cfg.min_fpe_share(1);
        Self {
            fpe_bytes: self.fpe_bytes.max(min),
            bpe_bytes: if cfg.bpe_mem.is_some() {
                self.bpe_bytes.max(min)
            } else {
                self.bpe_bytes
            },
        }
    }
}

/// Why a tenant could not be admitted.  Returned *before* any engine
/// state is touched: a rejected admission leaves every resident
/// tenant byte-for-byte intact.
#[derive(Clone, Copy, Debug, PartialEq, Eq, thiserror::Error)]
pub enum AdmissionError {
    #[error("tree {tree} is already admitted")]
    AlreadyAdmitted { tree: TreeId },
    /// The ledger has too little free memory.  `reclaimable` reports
    /// how many bytes an elastic-reclaim pass over idle tenants could
    /// free, so callers can decide whether to retry with reclamation.
    #[error(
        "{stage} quota for tree {tree} cannot be met: requested {requested} B, \
         {free} B free ({reclaimable} B reclaimable from idle tenants)"
    )]
    QuotaExhausted {
        tree: TreeId,
        stage: &'static str,
        requested: u64,
        free: u64,
        reclaimable: u64,
    },
    /// The share rounds down to zero slots in the widest key group —
    /// the table would be built at the degenerate 1-slot floor and
    /// thrash.  `min` is the smallest viable share for this lane width.
    #[error(
        "{stage} share of {share} B for tree {tree} rounds to zero slots in the \
         widest key group (minimum viable share is {min} B)"
    )]
    ZeroCapacity {
        tree: TreeId,
        stage: &'static str,
        share: u64,
        min: u64,
    },
}

/// Residual aggregation state drained from an evicted tenant.
#[derive(Debug, Clone, Default)]
pub struct EvictedResidents {
    /// Scalar (W = 1) resident pairs.
    pub pairs: Vec<KvPair>,
    /// W-lane resident pairs (set only for vector tenants).
    pub vector: Option<VectorBatch>,
}

impl EvictedResidents {
    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty() && self.vector.as_ref().map_or(true, |v| v.is_empty())
    }
}

/// One resident tree: its engine plus the bookkeeping that makes it
/// individually admissible, evictable, and resizable.
pub(crate) struct Tenant {
    pub(crate) config: TreeConfig,
    pub(crate) engine: TreeEngine,
    pub(crate) lanes: usize,
    /// `None` for legacy static-split trees installed via `configure()`
    /// — those are rebuilt wholesale by the config module and never
    /// charged against the quota ledger.
    pub(crate) quota: Option<QuotaRequest>,
    pub(crate) weight: u64,
    pub(crate) idle: bool,
    /// Bytes currently backing the engine (≤ quota after reclamation).
    pub(crate) fpe_share: u64,
    pub(crate) bpe_share: Option<u64>,
}

/// Every resident tree on one switch, plus the FPE/BPE memory ledger
/// quota-admitted tenants are charged against.  Legacy static-split
/// trees coexist (uncharged) so the pre-quota `configure()` API keeps
/// its exact behavior.
#[derive(Default)]
pub(crate) struct TenantDirectory {
    tenants: BTreeMap<TreeId, Tenant>,
    fpe_reserved: u64,
    bpe_reserved: u64,
}

impl TenantDirectory {
    pub(crate) fn new() -> Self {
        Self::default()
    }

    pub(crate) fn clear(&mut self) {
        self.tenants.clear();
        self.fpe_reserved = 0;
        self.bpe_reserved = 0;
    }

    pub(crate) fn len(&self) -> usize {
        self.tenants.len()
    }

    pub(crate) fn contains(&self, tree: TreeId) -> bool {
        self.tenants.contains_key(&tree)
    }

    pub(crate) fn ids(&self) -> impl Iterator<Item = TreeId> + '_ {
        self.tenants.keys().copied()
    }

    pub(crate) fn get(&self, tree: TreeId) -> Option<&Tenant> {
        self.tenants.get(&tree)
    }

    pub(crate) fn get_mut(&mut self, tree: TreeId) -> Option<&mut Tenant> {
        self.tenants.get_mut(&tree)
    }

    pub(crate) fn engine(&self, tree: TreeId) -> Option<&TreeEngine> {
        self.tenants.get(&tree).map(|t| &t.engine)
    }

    pub(crate) fn engine_mut(&mut self, tree: TreeId) -> Option<&mut TreeEngine> {
        self.tenants.get_mut(&tree).map(|t| &mut t.engine)
    }

    /// Install (or replace) a legacy static-split tree.  Not charged
    /// against the ledger; quota state of a previous incarnation is
    /// released first.
    pub(crate) fn install_legacy(
        &mut self,
        config: TreeConfig,
        engine: TreeEngine,
        lanes: usize,
    ) {
        let tree = config.tree;
        self.release(tree);
        self.tenants.insert(
            tree,
            Tenant {
                config,
                engine,
                lanes,
                quota: None,
                weight: 1,
                idle: false,
                fpe_share: 0,
                bpe_share: None,
            },
        );
    }

    /// Drop `tree`'s ledger charge (if any) ahead of replace/remove.
    fn release(&mut self, tree: TreeId) {
        if let Some(t) = self.tenants.get(&tree) {
            if t.quota.is_some() {
                self.fpe_reserved = self.fpe_reserved.saturating_sub(t.fpe_share);
                self.bpe_reserved = self
                    .bpe_reserved
                    .saturating_sub(t.bpe_share.unwrap_or(0));
            }
        }
    }

    pub(crate) fn free_fpe(&self, cfg: &SwitchConfig) -> u64 {
        cfg.fpe_total_mem.saturating_sub(self.fpe_reserved)
    }

    pub(crate) fn free_bpe(&self, cfg: &SwitchConfig) -> u64 {
        cfg.bpe_mem.unwrap_or(0).saturating_sub(self.bpe_reserved)
    }

    /// Bytes an elastic-reclaim pass could free from idle scalar
    /// quota tenants (shrinking each to the minimum viable share).
    pub(crate) fn reclaimable_fpe(&self, cfg: &SwitchConfig) -> u64 {
        let floor = cfg.min_fpe_share(1);
        self.tenants
            .values()
            .filter(|t| t.idle && t.lanes == 1 && t.quota.is_some())
            .map(|t| t.fpe_share.saturating_sub(floor))
            .sum()
    }

    /// Admit a new tenant against the ledger.  Validates the quota
    /// (zero-capacity rounding, then headroom) before building any
    /// engine state, so rejection is side-effect free.
    pub(crate) fn admit(
        &mut self,
        cfg: &SwitchConfig,
        config: TreeConfig,
        quota: QuotaRequest,
        lanes: usize,
        weight: u64,
    ) -> Result<(), AdmissionError> {
        let tree = config.tree;
        if self.tenants.contains_key(&tree) {
            return Err(AdmissionError::AlreadyAdmitted { tree });
        }
        let min = cfg.min_fpe_share(lanes);
        if quota.fpe_bytes < min {
            return Err(AdmissionError::ZeroCapacity {
                tree,
                stage: "FPE",
                share: quota.fpe_bytes,
                min,
            });
        }
        let free = self.free_fpe(cfg);
        if quota.fpe_bytes > free {
            return Err(AdmissionError::QuotaExhausted {
                tree,
                stage: "FPE",
                requested: quota.fpe_bytes,
                free,
                reclaimable: self.reclaimable_fpe(cfg),
            });
        }
        let bpe_share = cfg.bpe_mem.map(|_| quota.bpe_bytes);
        if let Some(share) = bpe_share {
            if share < min {
                return Err(AdmissionError::ZeroCapacity {
                    tree,
                    stage: "BPE",
                    share,
                    min,
                });
            }
            let free = self.free_bpe(cfg);
            if share > free {
                return Err(AdmissionError::QuotaExhausted {
                    tree,
                    stage: "BPE",
                    requested: share,
                    free,
                    reclaimable: 0,
                });
            }
        }
        let engine = TreeEngine::new(
            cfg,
            config.op,
            config.children,
            quota.fpe_bytes,
            bpe_share,
            lanes,
        );
        self.fpe_reserved += quota.fpe_bytes;
        self.bpe_reserved += bpe_share.unwrap_or(0);
        self.tenants.insert(
            tree,
            Tenant {
                config,
                engine,
                lanes,
                quota: Some(quota),
                weight: weight.max(1),
                idle: false,
                fpe_share: quota.fpe_bytes,
                bpe_share,
            },
        );
        Ok(())
    }

    /// Shrink idle scalar quota tenants (never `protect`) toward the
    /// minimum viable share until the requested headroom exists or
    /// nothing reclaimable remains.  Returns each shrunken tenant's
    /// drained residents for software merge.
    pub(crate) fn reclaim(
        &mut self,
        cfg: &SwitchConfig,
        need_fpe: u64,
        need_bpe: u64,
        protect: TreeId,
    ) -> Vec<(TreeId, Vec<KvPair>)> {
        let floor = cfg.min_fpe_share(1);
        let mut spilled = Vec::new();
        let ids: Vec<TreeId> = self.tenants.keys().copied().collect();
        for id in ids {
            if self.free_fpe(cfg) >= need_fpe && self.free_bpe(cfg) >= need_bpe {
                break;
            }
            if id == protect {
                continue;
            }
            let t = self.tenants.get_mut(&id).unwrap();
            if !t.idle || t.lanes != 1 || t.quota.is_none() {
                continue;
            }
            let new_fpe = floor.min(t.fpe_share);
            let new_bpe = t.bpe_share.map(|s| floor.min(s));
            if new_fpe == t.fpe_share && new_bpe == t.bpe_share {
                continue;
            }
            let mut out = Vec::new();
            t.engine.resize_to(cfg, new_fpe, new_bpe, &mut out);
            self.fpe_reserved -= t.fpe_share - new_fpe;
            if let (Some(old), Some(new)) = (t.bpe_share, new_bpe) {
                self.bpe_reserved -= old - new;
            }
            t.fpe_share = new_fpe;
            t.bpe_share = new_bpe;
            spilled.push((id, out));
        }
        spilled
    }

    /// Grow a previously reclaimed tenant back toward its quota if the
    /// ledger now has headroom.  Returns drained residents (normally
    /// empty: regrow happens between jobs, after a flush) or `None` if
    /// the tenant is unknown, already at quota, or headroom is
    /// insufficient.
    pub(crate) fn regrow(
        &mut self,
        cfg: &SwitchConfig,
        tree: TreeId,
    ) -> Option<Vec<KvPair>> {
        let free_fpe = self.free_fpe(cfg);
        let free_bpe = self.free_bpe(cfg);
        let t = self.tenants.get_mut(&tree)?;
        let quota = t.quota?;
        if t.lanes != 1 {
            return None;
        }
        let want_bpe = t.bpe_share.map(|_| quota.bpe_bytes);
        let grow_fpe = quota.fpe_bytes.saturating_sub(t.fpe_share);
        let grow_bpe = want_bpe
            .zip(t.bpe_share)
            .map_or(0, |(w, s)| w.saturating_sub(s));
        if grow_fpe == 0 && grow_bpe == 0 {
            return None;
        }
        if grow_fpe > free_fpe || grow_bpe > free_bpe {
            return None;
        }
        let mut out = Vec::new();
        t.engine.resize_to(cfg, quota.fpe_bytes, want_bpe, &mut out);
        self.fpe_reserved += grow_fpe;
        self.bpe_reserved += grow_bpe;
        t.fpe_share = quota.fpe_bytes;
        t.bpe_share = want_bpe;
        Some(out)
    }

    /// Remove a tenant, releasing its ledger charge and draining its
    /// resident aggregation state.  Neighbors are untouched.
    pub(crate) fn evict(&mut self, tree: TreeId) -> Option<EvictedResidents> {
        self.release(tree);
        let mut t = self.tenants.remove(&tree)?;
        let mut out = EvictedResidents::default();
        if t.lanes == 1 {
            t.engine.drain_residents(&mut out.pairs);
        } else {
            let mut batch = VectorBatch::new(t.lanes);
            t.engine.drain_residents_vector(&mut batch);
            out.vector = Some(batch);
        }
        Some(out)
    }

    pub(crate) fn set_idle(&mut self, tree: TreeId, idle: bool) {
        if let Some(t) = self.tenants.get_mut(&tree) {
            t.idle = idle;
        }
    }

    pub(crate) fn set_weight(&mut self, tree: TreeId, weight: u64) {
        if let Some(t) = self.tenants.get_mut(&tree) {
            t.weight = weight.max(1);
        }
    }

    pub(crate) fn weight_of(&self, tree: TreeId) -> u64 {
        self.tenants.get(&tree).map_or(1, |t| t.weight)
    }

    /// Sum of active (non-idle) tenants' weights — the denominator for
    /// weighted credit grants.
    pub(crate) fn busy_weight(&self) -> u64 {
        self.tenants
            .values()
            .filter(|t| !t.idle)
            .map(|t| t.weight)
            .sum()
    }

    /// Count of active (non-idle) tenants.
    pub(crate) fn busy_tenants(&self) -> usize {
        self.tenants.values().filter(|t| !t.idle).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn cfg() -> SwitchConfig {
        SwitchConfig::scaled(64 << 10, Some(1 << 20))
    }

    fn tc(id: u32, children: u16) -> TreeConfig {
        TreeConfig {
            tree: TreeId(id),
            op: AggOp::Sum,
            children,
            parent_port: 0,
        }
    }

    fn pairs(n: u64, distinct: u64, seed: u64) -> Vec<KvPair> {
        (0..n)
            .map(|i| {
                let id = (i * 7 + seed) % distinct;
                KvPair::new(Key::from_id(id, 16 + (id % 49) as usize), 1)
            })
            .collect()
    }

    #[test]
    fn admit_charges_and_evict_releases_the_ledger() {
        let c = cfg();
        let mut dir = TenantDirectory::new();
        let q = QuotaRequest::even_split(&c, 4);
        dir.admit(&c, tc(1, 2), q, 1, 1).unwrap();
        dir.admit(&c, tc(2, 2), q, 1, 1).unwrap();
        assert_eq!(dir.free_fpe(&c), c.fpe_total_mem - 2 * q.fpe_bytes);
        let res = dir.evict(TreeId(1)).unwrap();
        assert!(res.is_empty(), "fresh engine has no residents");
        assert_eq!(dir.free_fpe(&c), c.fpe_total_mem - q.fpe_bytes);
        assert!(!dir.contains(TreeId(1)));
        assert!(dir.contains(TreeId(2)));
    }

    #[test]
    fn double_admission_is_typed() {
        let c = cfg();
        let mut dir = TenantDirectory::new();
        let q = QuotaRequest::even_split(&c, 4);
        dir.admit(&c, tc(1, 2), q, 1, 1).unwrap();
        assert_eq!(
            dir.admit(&c, tc(1, 2), q, 1, 1),
            Err(AdmissionError::AlreadyAdmitted { tree: TreeId(1) })
        );
    }

    #[test]
    fn oversubscription_is_rejected_with_headroom_report() {
        let c = cfg();
        let mut dir = TenantDirectory::new();
        let q = QuotaRequest::even_split(&c, 2);
        dir.admit(&c, tc(1, 2), q, 1, 1).unwrap();
        dir.admit(&c, tc(2, 2), q, 1, 1).unwrap();
        match dir.admit(&c, tc(3, 2), q, 1, 1) {
            Err(AdmissionError::QuotaExhausted {
                stage: "FPE",
                requested,
                free,
                ..
            }) => {
                assert_eq!(requested, q.fpe_bytes);
                assert_eq!(free, 0);
            }
            other => panic!("expected FPE QuotaExhausted, got {other:?}"),
        }
        // The failed admission left the residents untouched.
        assert_eq!(dir.len(), 2);
    }

    #[test]
    fn zero_capacity_quota_is_rejected_before_any_state_change() {
        let c = cfg();
        let mut dir = TenantDirectory::new();
        let min = c.min_fpe_share(1);
        let q = QuotaRequest {
            fpe_bytes: min - 1,
            bpe_bytes: 1 << 18,
        };
        assert_eq!(
            dir.admit(&c, tc(1, 2), q, 1, 1),
            Err(AdmissionError::ZeroCapacity {
                tree: TreeId(1),
                stage: "FPE",
                share: min - 1,
                min,
            })
        );
        assert_eq!(dir.len(), 0);
        assert_eq!(dir.free_fpe(&c), c.fpe_total_mem);
    }

    #[test]
    fn reclaim_shrinks_idle_tenants_and_spills_their_residents() {
        let c = cfg();
        let mut dir = TenantDirectory::new();
        let big = QuotaRequest::even_split(&c, 2);
        dir.admit(&c, tc(1, 2), big, 1, 1).unwrap();
        dir.admit(&c, tc(2, 2), big, 1, 1).unwrap();

        // Park some aggregation state in tenant 1, then idle it.
        let input = pairs(500, 200, 3);
        let mut sink = IngestSink::new();
        dir.engine_mut(TreeId(1))
            .unwrap()
            .ingest_pairs(&input, false, 0, &mut sink);
        let resident = dir.engine(TreeId(1)).unwrap().resident_pairs();
        assert!(resident > 0, "expected resident pairs before reclaim");
        dir.set_idle(TreeId(1), true);

        // A third tenant does not fit until tenant 1 is reclaimed.
        let q = QuotaRequest::even_split(&c, 4).at_least_floor(&c);
        assert!(matches!(
            dir.admit(&c, tc(3, 2), q, 1, 1),
            Err(AdmissionError::QuotaExhausted { .. })
        ));
        let spilled = dir.reclaim(&c, q.fpe_bytes, q.bpe_bytes, TreeId(3));
        assert_eq!(spilled.len(), 1);
        assert_eq!(spilled[0].0, TreeId(1));
        dir.admit(&c, tc(3, 2), q, 1, 1).unwrap();

        // Nothing was lost: spilled pairs + still-resident pairs merged
        // in software equal the tenant's pre-reclaim aggregate.
        let mut merged: HashMap<Key, Value> = HashMap::new();
        for p in spilled[0].1.iter() {
            *merged.entry(p.key).or_insert(0) += p.value;
        }
        let mut drained = Vec::new();
        dir.engine_mut(TreeId(1)).unwrap().drain_residents(&mut drained);
        for p in &drained {
            *merged.entry(p.key).or_insert(0) += p.value;
        }
        for p in &sink.forwarded {
            *merged.entry(p.key).or_insert(0) += p.value;
        }
        let mut expect: HashMap<Key, Value> = HashMap::new();
        for p in &input {
            *expect.entry(p.key).or_insert(0) += p.value;
        }
        assert_eq!(merged, expect, "reclaim must never lose or corrupt pairs");
    }

    #[test]
    fn regrow_restores_quota_when_headroom_returns() {
        let c = cfg();
        let mut dir = TenantDirectory::new();
        let big = QuotaRequest::even_split(&c, 2);
        dir.admit(&c, tc(1, 2), big, 1, 1).unwrap();
        dir.set_idle(TreeId(1), true);
        let shrunk = dir.reclaim(&c, c.fpe_total_mem, 0, TreeId(99));
        assert_eq!(shrunk.len(), 1);
        let floor = c.min_fpe_share(1);
        assert_eq!(dir.get(TreeId(1)).unwrap().fpe_share, floor);
        let residents = dir.regrow(&c, TreeId(1)).unwrap();
        assert!(residents.is_empty());
        assert_eq!(dir.get(TreeId(1)).unwrap().fpe_share, big.fpe_bytes);
        assert_eq!(dir.free_fpe(&c), c.fpe_total_mem - big.fpe_bytes);
        // Already at quota: a second regrow is a no-op.
        assert!(dir.regrow(&c, TreeId(1)).is_none());
    }

    #[test]
    fn reclaim_skips_busy_protected_and_vector_tenants() {
        let c = cfg();
        let mut dir = TenantDirectory::new();
        let q = QuotaRequest::even_split(&c, 4);
        dir.admit(&c, tc(1, 2), q, 1, 1).unwrap(); // stays busy
        dir.admit(&c, tc(2, 2), q, 8, 1).unwrap(); // vector, idle
        dir.admit(&c, tc(3, 2), q, 1, 1).unwrap(); // protected, idle
        dir.set_idle(TreeId(2), true);
        dir.set_idle(TreeId(3), true);
        let spilled = dir.reclaim(&c, c.fpe_total_mem, 0, TreeId(3));
        assert!(spilled.is_empty(), "no eligible tenant to reclaim");
        for id in [1u32, 2, 3] {
            assert_eq!(dir.get(TreeId(id)).unwrap().fpe_share, q.fpe_bytes);
        }
    }

    #[test]
    fn resize_preserves_engine_counters() {
        let c = cfg();
        let mut dir = TenantDirectory::new();
        dir.admit(&c, tc(1, 2), QuotaRequest::full(&c), 1, 1).unwrap();
        let input = pairs(300, 120, 9);
        let mut sink = IngestSink::new();
        dir.engine_mut(TreeId(1))
            .unwrap()
            .ingest_pairs(&input, false, 0, &mut sink);
        let before = format!("{:?}", dir.engine(TreeId(1)).unwrap().stats);
        let mut out = Vec::new();
        dir.engine_mut(TreeId(1)).unwrap().resize_to(
            &c,
            c.min_fpe_share(1),
            c.bpe_mem.map(|_| c.min_fpe_share(1)),
            &mut out,
        );
        let after = format!("{:?}", dir.engine(TreeId(1)).unwrap().stats);
        assert_eq!(before, after, "resize must not perturb cumulative stats");
        assert!(!out.is_empty());
    }
}
