//! Application-level metric models: job completion time (Fig. 10) and
//! CPU utilization (Fig. 11).

pub mod cpu;
pub mod jct;

pub use cpu::CpuModel;
pub use jct::{JctBreakdown, JctModel};
