//! Job-completion-time model (Fig. 10).
//!
//! The testbed (§6.1) is 3 mappers and 1 reducer on 10 GbE through the
//! switch.  Phases overlap in a streaming pipeline, so
//!
//! ```text
//! JCT = max( mapper send time,            // 3 parallel 10G links
//!            reducer receive time,        // switch output into 1 link
//!            reducer software aggregation // CPU-bound arm
//!      ) + flush tail + residual merge
//! ```
//!
//! *Without* SwitchAgg every mapper byte converges on the reducer's
//! single in-bound link (the in-cast problem of §1) and the reducer
//! aggregates everything in software.  *With* SwitchAgg the receive and
//! CPU arms shrink by the switch's reduction ratio; the price is the
//! BPE flush tail (Table 3), which is why small workloads see little
//! gain — exactly the paper's "in some cases the result of with- and
//! without SwitchAgg is similar".

use crate::metrics::cpu::CpuModel;
use crate::sim::clock::cycles_to_secs;
use crate::sim::{Cycles, Link};

/// Scenario parameters.
#[derive(Clone, Debug)]
pub struct JctModel {
    pub n_mappers: usize,
    pub link: Link,
    pub cpu: CpuModel,
}

impl Default for JctModel {
    fn default() -> Self {
        Self {
            n_mappers: 3,
            link: Link::ten_gbe(),
            cpu: CpuModel::default(),
        }
    }
}

/// Phase breakdown of one job.
#[derive(Clone, Copy, Debug)]
pub struct JctBreakdown {
    pub map_send_s: f64,
    pub reduce_recv_s: f64,
    pub reduce_cpu_s: f64,
    pub flush_tail_s: f64,
    pub total_s: f64,
}

impl JctModel {
    /// JCT for a job that injects `input_bytes` (`input_pairs`) at the
    /// mappers, of which `output_bytes` (`output_pairs`) reach the
    /// reducer after in-network aggregation, with a `flush_cycles`
    /// drain tail inside the switch.  For the no-aggregation baseline,
    /// pass input == output and `flush_cycles = 0`.
    pub fn job(
        &self,
        input_bytes: u64,
        input_pairs: u64,
        output_bytes: u64,
        output_pairs: u64,
        flush_cycles: Cycles,
    ) -> JctBreakdown {
        let map_send_s = self
            .link
            .transfer_secs(input_bytes.div_ceil(self.n_mappers as u64));
        let reduce_recv_s = self.link.transfer_secs(output_bytes);
        let reduce_cpu_s = self.cpu.aggregate_secs(output_pairs, output_bytes);
        let flush_tail_s = cycles_to_secs(flush_cycles);
        let streaming = map_send_s.max(reduce_recv_s).max(reduce_cpu_s);
        let _ = input_pairs;
        JctBreakdown {
            map_send_s,
            reduce_recv_s,
            reduce_cpu_s,
            flush_tail_s,
            total_s: streaming + flush_tail_s,
        }
    }

    /// Convenience pair: (with SwitchAgg, without SwitchAgg).
    pub fn compare(
        &self,
        input_bytes: u64,
        input_pairs: u64,
        output_bytes: u64,
        output_pairs: u64,
        flush_cycles: Cycles,
    ) -> (JctBreakdown, JctBreakdown) {
        let with = self.job(
            input_bytes,
            input_pairs,
            output_bytes,
            output_pairs,
            flush_cycles,
        );
        let without = self.job(input_bytes, input_pairs, input_bytes, input_pairs, 0);
        (with, without)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_agg_is_incast_bound() {
        let m = JctModel::default();
        let b = m.job(3 << 30, 60_000_000, 3 << 30, 60_000_000, 0);
        // Receive over one link is 3x the per-mapper send time.
        assert!(b.reduce_recv_s > 2.9 * b.map_send_s);
        assert!(b.total_s >= b.reduce_recv_s);
    }

    #[test]
    fn high_reduction_shifts_bottleneck_to_mappers() {
        let m = JctModel::default();
        let (with, without) = m.compare(3 << 30, 60_000_000, 3 << 25, 2_000_000, 0);
        assert!(with.total_s < without.total_s);
        // With 99% reduction the map-send arm dominates.
        assert!((with.total_s - with.map_send_s).abs() / with.total_s < 0.05);
        // Savings approach the paper's ~50% plateau: incast (3 links
        // into 1) plus CPU relief bounds at >2x here.
        assert!(without.total_s / with.total_s > 1.5);
    }

    #[test]
    fn flush_tail_erodes_small_job_gains() {
        let m = JctModel::default();
        // Tiny job, big flush: SwitchAgg may not win (paper's
        // "overhead offsets its benefits").
        let flush: u64 = 31_250_000; // Table 3 BPE-Flush
        let (with, without) = m.compare(64 << 20, 1_400_000, 1 << 20, 20_000, flush);
        assert!(with.flush_tail_s > 0.1);
        assert!(with.total_s > 0.9 * without.total_s, "flush tail should bite");
    }

    #[test]
    fn jct_grows_with_workload() {
        let m = JctModel::default();
        let small = m.job(1 << 30, 20_000_000, 1 << 28, 5_000_000, 0);
        let big = m.job(4 << 30, 80_000_000, 1 << 30, 20_000_000, 0);
        assert!(big.total_s > 3.0 * small.total_s);
    }
}
