//! Reducer CPU model (Fig. 11).
//!
//! The paper reports *average CPU utilization during job execution* on
//! the reducer host (2×12-core Xeon E5-2658A).  Software aggregation
//! cost is dominated by per-pair hash-map operations plus per-byte
//! parsing; the constants below are calibrated against this repo's own
//! measured software reducer (`framework::reducer`, see EXPERIMENTS.md
//! §Calibration) and can be overridden.

/// Per-host CPU cost model.
#[derive(Clone, Copy, Debug)]
pub struct CpuModel {
    pub cores: u32,
    /// Cost of one hash-map aggregate (ns).
    pub per_pair_ns: f64,
    /// Cost of parsing one payload byte (ns).
    pub per_byte_ns: f64,
}

impl Default for CpuModel {
    fn default() -> Self {
        Self {
            cores: 24,
            per_pair_ns: 65.0,
            per_byte_ns: 0.35,
        }
    }
}

impl CpuModel {
    /// Wall-clock seconds of software aggregation for a stream
    /// (single-threaded reducer, as in the paper's framework).
    pub fn aggregate_secs(&self, pairs: u64, bytes: u64) -> f64 {
        (pairs as f64 * self.per_pair_ns + bytes as f64 * self.per_byte_ns) * 1e-9
    }

    /// Average utilization (fraction of the whole host, 0..=1) while a
    /// job of duration `jct_s` spends `busy_s` single-core-seconds on
    /// aggregation plus a fixed networking overhead per received byte.
    pub fn utilization(&self, busy_s: f64, jct_s: f64) -> f64 {
        if jct_s <= 0.0 {
            return 0.0;
        }
        (busy_s / (jct_s * self.cores as f64)).min(1.0)
    }

    /// Utilization of a reducer that aggregates `pairs`/`bytes` over a
    /// job of `jct_s` seconds.
    pub fn reducer_utilization(&self, pairs: u64, bytes: u64, jct_s: f64) -> f64 {
        self.utilization(self.aggregate_secs(pairs, bytes), jct_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregation_cost_scales() {
        let m = CpuModel::default();
        let one = m.aggregate_secs(1_000_000, 46_000_000);
        let ten = m.aggregate_secs(10_000_000, 460_000_000);
        assert!((ten / one - 10.0).abs() < 1e-9);
        // ~65ns per pair: 1M pairs ≈ 81ms with parsing.
        assert!(one > 0.05 && one < 0.15, "{one}");
    }

    #[test]
    fn utilization_bounds() {
        let m = CpuModel::default();
        assert_eq!(m.utilization(0.0, 10.0), 0.0);
        assert_eq!(m.utilization(1e9, 1.0), 1.0); // clamped
        let u = m.utilization(12.0, 1.0);
        assert!((u - 0.5).abs() < 1e-9); // 12 core-seconds of 24 cores
    }

    #[test]
    fn fewer_pairs_less_utilization() {
        let m = CpuModel::default();
        let jct = 2.0;
        let with = m.reducer_utilization(100_000, 4_600_000, jct);
        let without = m.reducer_utilization(10_000_000, 460_000_000, jct);
        assert!(without > 5.0 * with);
    }
}
