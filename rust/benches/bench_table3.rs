//! Bench + regeneration of Table 3 (per-stage processing delay).

use switchagg::experiments::{table3, Scale};
use switchagg::util::bench;

fn main() {
    let scale = Scale::default();
    bench::section("Table 3 — processing delay per stage");
    let rows = table3::run(scale);
    table3::print_rows(&rows, scale);
    bench::run("table3 instrumented run", 1, 5, || {
        table3::run(scale).len() as u64
    });
}
