//! Serving-driver benchmarks (EXPERIMENTS.md §Multi-tenancy &
//! isolation): what multi-tenant serving costs.  Structural claims
//! under test: (1) the tenancy driver's single-tenant zero-churn
//! overhead over the plain transport driver is small — slot/gen
//! fencing is a cheap tag decode per delivery; (2) serving N tenants
//! concurrently costs per-packet work, not per-tenant work — items/s
//! should hold as the tenant count grows; (3) admission/eviction churn
//! (depart-between-jobs tenants) stays off the delivery hot path.
//! Items = transport packets put on the wire (data first-tx +
//! retransmissions, both hops, summed over completed jobs), so
//! items/s is comparable against `BENCH_transport.json` and
//! `BENCH_faults.json`.  Results land in `BENCH_tenancy.json`
//! (override with `SWITCHAGG_BENCH_TENANCY_JSON`).

use switchagg::framework::transport::{run_transport_scalar, TransportConfig};
use switchagg::framework::{run_tenancy, TenancyRegime, TenancyRun, TenantJob, TenantSpec};
use switchagg::protocol::{AggOp, Key, KvPair, TreeConfig, TreeId};
use switchagg::switch::{QuotaRequest, SwitchAggSwitch, SwitchConfig};
use switchagg::util::bench::{self, JsonLog};
use switchagg::util::rng::Pcg32;

fn switch_cfg() -> SwitchConfig {
    SwitchConfig::scaled(32 << 10, Some(8 << 20))
}

fn streams(children: usize, pairs: usize, seed: u64) -> Vec<Vec<KvPair>> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0x7E);
            (0..pairs)
                .map(|_| {
                    let id = child.gen_range_u64((pairs as u64 / 4).max(64));
                    KvPair::new(
                        Key::from_id(id, 16 + (id % 49) as usize),
                        child.gen_range_u64(100) as i64 - 50,
                    )
                })
                .collect()
        })
        .collect()
}

fn wire_packets(run: &TenancyRun) -> u64 {
    run.outcomes
        .iter()
        .map(|o| {
            o.ingress.first_tx
                + o.ingress.retransmissions
                + o.egress.first_tx
                + o.egress.retransmissions
        })
        .sum()
}

fn spec(slot: usize, jobs: usize, children: usize, pairs: usize, depart: bool) -> TenantSpec {
    let cfg = switch_cfg();
    TenantSpec {
        tree: TreeId(slot as u32 + 1),
        children: children as u16,
        op: AggOp::Sum,
        weight: 1,
        quota: QuotaRequest::even_split(&cfg, 8),
        evict_between_jobs: depart,
        jobs: (0..jobs)
            .map(|j| TenantJob {
                start_s: 0.0,
                streams: streams(children, pairs, 0x7E00 + (slot * 31 + j) as u64),
            })
            .collect(),
    }
}

fn serve(specs: &[TenantSpec], regime: TenancyRegime) -> u64 {
    let mut sw = SwitchAggSwitch::new(switch_cfg());
    if regime == TenancyRegime::StaticSplit {
        let trees: Vec<TreeConfig> = specs
            .iter()
            .map(|s| TreeConfig {
                tree: s.tree,
                children: s.children,
                parent_port: 0,
                op: s.op,
            })
            .collect();
        sw.configure(&trees);
    }
    let run = run_tenancy(&mut sw, specs, regime, &TransportConfig::default());
    assert_eq!(run.rejected, 0, "bench workload must not bounce");
    wire_packets(&run)
}

fn main() {
    let mut log = JsonLog::new();
    let pairs = 4_000usize;

    bench::section("single-tenant zero-churn overhead (vs plain transport)");
    log.push(&bench::run("plain transport 1 tenant", 1, 5, move || {
        let ss = streams(4, pairs, 0x7E00);
        let mut sw = SwitchAggSwitch::new(switch_cfg());
        sw.configure(&[TreeConfig {
            tree: TreeId(1),
            children: 4,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        let run =
            run_transport_scalar(&mut sw, TreeId(1), AggOp::Sum, &ss, &TransportConfig::default());
        run.ingress.first_tx
            + run.ingress.retransmissions
            + run.egress.first_tx
            + run.egress.retransmissions
    }));
    log.push(&bench::run("tenancy driver 1 tenant", 1, 5, move || {
        serve(&[spec(0, 1, 4, pairs, false)], TenancyRegime::StaticSplit)
    }));

    bench::section("concurrent serving (8 tenants, same total bytes)");
    fn fleet(pairs: usize, jobs: usize, depart: bool) -> Vec<TenantSpec> {
        (0..8).map(|s| spec(s, jobs, 2, pairs / 4, depart)).collect()
    }
    log.push(&bench::run("8 tenants static split", 1, 5, move || {
        serve(&fleet(pairs, 1, false), TenancyRegime::StaticSplit)
    }));
    log.push(&bench::run("8 tenants quota+wfq", 1, 5, move || {
        serve(&fleet(pairs, 1, false), TenancyRegime::QuotaWeighted)
    }));
    // Three jobs each with depart-between-jobs: every completion is an
    // eviction and every arrival a fresh admission.
    log.push(&bench::run("8 tenants quota, churn", 1, 5, move || {
        serve(&fleet(pairs, 3, true), TenancyRegime::QuotaReclaim)
    }));

    let path = std::env::var("SWITCHAGG_BENCH_TENANCY_JSON")
        .unwrap_or_else(|_| "BENCH_tenancy.json".to_string());
    if let Err(e) = log.write(&path) {
        eprintln!("could not write bench log {path}: {e}");
    }
}
