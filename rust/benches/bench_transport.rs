//! Transport-driver benchmarks (EXPERIMENTS.md §Incast & congestion
//! control): the event-driven NetSim co-simulation
//! (`framework::transport`) against the retained tick-based reference
//! (`framework::reliable`) on identical workloads.  The structural
//! claim under test: the tick loop's cost scales with *simulated
//! rounds* (every tick scans every sender's in-flight window, sending
//! or not), the event driver's with *packets processed* (idle timer
//! gaps are jumped in O(1)).  Items = transport packets put on the
//! wire (data first-tx + retransmissions, both hops), so items/s is
//! the drivers' comparable throughput.  Results are written as a
//! machine-readable log (`BENCH_transport.json`, override with
//! `SWITCHAGG_BENCH_TRANSPORT_JSON`).

use switchagg::framework::reliable::{run_reliable_scalar, ReliabilityConfig};
use switchagg::framework::transport::{run_transport_scalar, CreditMode, TransportConfig};
use switchagg::protocol::{AggOp, Key, KvPair, RelWindow, TreeConfig, TreeId};
use switchagg::switch::{SwitchAggSwitch, SwitchConfig};
use switchagg::util::bench::{self, JsonLog};
use switchagg::util::rng::Pcg32;

fn streams(children: usize, pairs: usize, seed: u64) -> Vec<Vec<KvPair>> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0xbe);
            (0..pairs)
                .map(|_| {
                    let id = child.gen_range_u64((pairs as u64 / 4).max(64));
                    KvPair::new(
                        Key::from_id(id, 16 + (id % 49) as usize),
                        child.gen_range_u64(100) as i64 - 50,
                    )
                })
                .collect()
        })
        .collect()
}

fn switch_for(children: usize) -> SwitchAggSwitch {
    let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(32 << 10, Some(8 << 20)));
    sw.configure(&[TreeConfig {
        tree: TreeId(1),
        children: children as u16,
        parent_port: 0,
        op: AggOp::Sum,
    }]);
    sw
}

/// Wire packets both hops moved (the work denominator shared by the
/// two drivers — identical workload ⇒ comparable items/s).
fn tick_session(children: usize, pairs: usize, loss: f64, window: Option<RelWindow>) -> u64 {
    let ss = streams(children, pairs, 0xBE7C);
    let mut sw = switch_for(children);
    let mut cfg = if loss > 0.0 {
        ReliabilityConfig::uniform(loss, 0x5EED)
    } else {
        ReliabilityConfig::default()
    };
    if let Some(w) = window {
        cfg = cfg.with_window(w);
    }
    let run = run_reliable_scalar(&mut sw, TreeId(1), AggOp::Sum, &ss, &cfg);
    run.ingress.first_tx
        + run.ingress.retransmissions
        + run.egress.first_tx
        + run.egress.retransmissions
}

fn event_session(children: usize, pairs: usize, loss: f64, window: Option<RelWindow>) -> u64 {
    let ss = streams(children, pairs, 0xBE7C);
    let mut sw = switch_for(children);
    let mut cfg = TransportConfig::uniform(loss, 0x5EED);
    if let Some(w) = window {
        // Drip-window case: pin both drivers to the same fixed small
        // window so only the driver machinery differs.
        cfg = cfg.with_window(w).with_mode(CreditMode::FixedWindow);
    }
    let run = run_transport_scalar(&mut sw, TreeId(1), AggOp::Sum, &ss, &cfg);
    run.ingress.first_tx
        + run.ingress.retransmissions
        + run.egress.first_tx
        + run.egress.retransmissions
}

fn main() {
    let mut log = JsonLog::new();

    bench::section("tick loop vs event-driven co-simulation (full sessions)");
    for &(name, children, pairs, loss) in &[
        ("8x fan-in 1% loss", 8usize, 4_000usize, 0.01f64),
        ("64x fan-in 5% loss", 64, 1_000, 0.05),
    ] {
        log.push(&bench::run(
            &format!("tick driver {name}"),
            1,
            5,
            move || tick_session(children, pairs, loss, None),
        ));
        log.push(&bench::run(
            &format!("event driver {name}"),
            1,
            5,
            move || event_session(children, pairs, loss, None),
        ));
    }

    bench::section("drip window w=4 (tick cost ∝ rounds, event cost ∝ packets)");
    // A 4-packet window forces dozens of window-limited rounds: the
    // tick loop burns one full per-sender scan per round, the event
    // driver only touches the packets that actually move.
    let w = RelWindow::new(4);
    log.push(&bench::run("tick driver drip w=4 16x", 1, 5, move || {
        tick_session(16, 4_000, 0.0, Some(w))
    }));
    log.push(&bench::run("event driver drip w=4 16x", 1, 5, move || {
        event_session(16, 4_000, 0.0, Some(w))
    }));

    let path = std::env::var("SWITCHAGG_BENCH_TRANSPORT_JSON")
        .unwrap_or_else(|_| "BENCH_transport.json".to_string());
    if let Err(e) = log.write(&path) {
        eprintln!("could not write bench log {path}: {e}");
    }
}
