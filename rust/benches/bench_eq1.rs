//! Bench + regeneration of the Eq. 1 / Eq. 2 analysis (§2.2.1).

use switchagg::experiments::eq1;
use switchagg::util::bench;

fn main() {
    bench::section("Eq. 1 / Eq. 2 — RMT extra-traffic analysis");
    let rows = eq1::run();
    eq1::print_rows(&rows);
    bench::run("eq1 model + DAIET measurement", 1, 5, || {
        eq1::run().len() as u64
    });
}
