//! Failover-machinery benchmarks (EXPERIMENTS.md §Failover & state
//! migration): what warm-standby replication costs.  Three structural
//! claims under test: (1) serializing a tree's full aggregation state
//! is a linear walk over the SoA arrays — snapshot and restore
//! throughput should sit near memcpy, not near the ingest path;
//! (2) incremental checkpoints ship only byte-dirtied regions, so
//! with a steady-rate workload their footprint is a small fraction of
//! the full-image cadence at identical install counts; (3) the
//! failover wrapper's zero-fault overhead over the plain transport
//! driver is small — the standby hooks are cheap predicates when no
//! standby is declared.  Results land in `BENCH_failover.json`
//! (override with `SWITCHAGG_BENCH_FAILOVER_JSON`).

use switchagg::framework::failover::{run_failover_scalar, FailoverConfig};
use switchagg::framework::transport::run_transport_scalar;
use switchagg::protocol::{AggOp, AggregationPacket, Key, KvPair, RelHeader, TreeConfig, TreeId};
use switchagg::switch::{IngestSink, SwitchAggSwitch, SwitchConfig, SwitchSnapshot};
use switchagg::util::bench::{self, JsonLog};
use switchagg::util::rng::Pcg32;

fn switch_cfg() -> SwitchConfig {
    SwitchConfig::scaled(32 << 10, Some(8 << 20))
}

fn streams(children: usize, pairs: usize, seed: u64) -> Vec<Vec<KvPair>> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0x5B);
            (0..pairs)
                .map(|_| {
                    let id = child.gen_range_u64((pairs as u64 / 4).max(64));
                    KvPair::new(
                        Key::from_id(id, 16 + (id % 49) as usize),
                        child.gen_range_u64(100) as i64 - 50,
                    )
                })
                .collect()
        })
        .collect()
}

fn configured(children: u16) -> SwitchAggSwitch {
    let mut sw = SwitchAggSwitch::new(switch_cfg());
    sw.configure(&[TreeConfig {
        tree: TreeId(1),
        children,
        parent_port: 0,
        op: AggOp::Sum,
    }]);
    sw
}

/// A switch mid-job: every stream ingested, no EoT yet (the state a
/// checkpoint actually captures).
fn loaded_switch(children: usize, pairs: usize) -> SwitchAggSwitch {
    let tree = TreeId(1);
    let mut sw = configured(children as u16);
    let mut sink = IngestSink::new();
    for (c, s) in streams(children, pairs, 0x5EED).iter().enumerate() {
        let mut pkts = AggregationPacket::pack_stream(tree, AggOp::Sum, s, false);
        for (i, p) in pkts.iter_mut().enumerate() {
            p.rel = Some(RelHeader {
                child: c as u16,
                epoch: 0,
                seq: i as u32 + 1,
            });
        }
        for p in &pkts {
            sw.ingest_reliable_one(tree, p, &mut sink);
        }
    }
    sw
}

fn wire_packets(
    ingress: &switchagg::framework::transport::NetHopStats,
    egress: &switchagg::framework::transport::NetHopStats,
) -> u64 {
    ingress.first_tx + ingress.retransmissions + egress.first_tx + egress.retransmissions
}

fn main() {
    let mut log = JsonLog::new();
    let tree = TreeId(1);
    let (children, pairs) = (8usize, 8_000usize);

    bench::section("snapshot / restore (items = snapshot bytes)");
    let sw = loaded_switch(children, pairs);
    log.push(&bench::run("snapshot 8x8k pairs", 1, 5, move || {
        sw.snapshot_tree(tree).expect("resident tree").to_bytes().len() as u64
    }));
    let bytes = loaded_switch(children, pairs)
        .snapshot_tree(tree)
        .expect("resident tree")
        .to_bytes();
    let mut target = configured(children as u16);
    log.push(&bench::run("decode + restore 8x8k pairs", 1, 5, move || {
        let snap = SwitchSnapshot::from_bytes(&bytes).expect("own encoding");
        target.restore_tree(&snap).expect("restore");
        bytes.len() as u64
    }));

    bench::section("checkpoint footprint (items = checkpoint wire bytes)");
    let ss = streams(children, pairs, 0xC4A1);
    let base_jct = run_failover_scalar(&switch_cfg(), AggOp::Sum, &ss, &FailoverConfig::default())
        .expect("fault-free baseline")
        .jct_s;
    for (name, incremental) in [("full images @10%jct", false), ("incremental @10%jct", true)] {
        let cfg = FailoverConfig {
            standby: true,
            checkpoint_period_s: Some(base_jct * 0.1),
            incremental,
            ..FailoverConfig::default()
        };
        let ss = ss.clone();
        log.push(&bench::run(name, 1, 3, move || {
            let run =
                run_failover_scalar(&switch_cfg(), AggOp::Sum, &ss, &cfg).expect("healthy run");
            assert!(run.checkpoints_installed >= 1);
            run.checkpoint_bytes
        }));
    }

    bench::section("zero-fault overhead (items = wire packets)");
    let ss2 = ss.clone();
    log.push(&bench::run("plain transport 8x", 1, 5, move || {
        let mut sw = configured(children as u16);
        let run = run_transport_scalar(
            &mut sw,
            tree,
            AggOp::Sum,
            &ss2,
            &FailoverConfig::default().transport,
        );
        wire_packets(&run.ingress, &run.egress)
    }));
    log.push(&bench::run("failover no standby 8x", 1, 5, move || {
        let run = run_failover_scalar(&switch_cfg(), AggOp::Sum, &ss, &FailoverConfig::default())
            .expect("zero-fault session");
        wire_packets(&run.ingress, &run.egress)
    }));

    let path = std::env::var("SWITCHAGG_BENCH_FAILOVER_JSON")
        .unwrap_or_else(|_| "BENCH_failover.json".to_string());
    if let Err(e) = log.write(&path) {
        eprintln!("could not write bench log {path}: {e}");
    }
}
