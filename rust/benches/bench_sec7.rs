//! Bench + regeneration of the §7 future-work evaluations
//! (aggregation-aware LogP, reduction-aware routing, weighted memory).

use switchagg::experiments::{sec7, Scale};
use switchagg::util::bench;

fn main() {
    let scale = Scale::default();
    bench::section("§7 — future-work features");
    sec7::run(scale);
    bench::run("sec7 suite", 0, 2, || {
        sec7::perfmodel_rows().len() as u64
            + sec7::routing_rows().len() as u64
            + sec7::memory_rows(scale).len() as u64
    });
}
