//! Bench + regeneration of the design-choice ablations (DESIGN.md).

use switchagg::experiments::{ablations, Scale};
use switchagg::util::bench;

fn main() {
    let scale = Scale::default();
    bench::section("Ablations — design choices");
    let rows = ablations::run(scale);
    ablations::print_rows(&rows);
    bench::run("ablation suite (6 variants)", 0, 2, || {
        ablations::run(scale).len() as u64
    });
}
