//! Pipeline-driver benchmarks (EXPERIMENTS.md §Streaming pipeline):
//! what the unified hop driver and the overlapped relay schedule cost
//! in simulator throughput.  Structural claims under test: (1) the
//! batch-mode pipeline driver — the zero-pipelining differential
//! baseline — adds no measurable overhead over the legacy two-phase
//! transport session it is pinned byte-identical to; (2) the
//! overlapped schedule pays only for the extra interleaved egress
//! events, not a per-pair tax (the stream packer is the same greedy
//! MTU walk `pack_stream` does); (3) the two-level rack→spine relay
//! scales with total packets carried, not with rack count.  Items =
//! transport packets put on the wire (data first-tx +
//! retransmissions, all hops, per job), comparable against
//! `BENCH_transport.json`.  Results land in `BENCH_pipeline.json`
//! (override with `SWITCHAGG_BENCH_PIPELINE_JSON`).

use switchagg::framework::transport::{run_transport_scalar, TransportConfig};
use switchagg::framework::{
    run_pipeline_scalar, run_pipeline_two_level, PipelineConfig,
};
use switchagg::protocol::{AggOp, Key, KvPair, TreeConfig, TreeId};
use switchagg::switch::{SwitchAggSwitch, SwitchConfig};
use switchagg::util::bench::{self, JsonLog};
use switchagg::util::rng::Pcg32;

/// Small key store so evictions stream mid-ingest — the overlapped
/// schedule must have a relay stream to drain or the bench measures
/// nothing.
fn switch(children: usize) -> SwitchAggSwitch {
    let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(16 << 10, Some(8 << 20)));
    sw.configure(&[TreeConfig {
        tree: TreeId(1),
        children: children as u16,
        parent_port: 0,
        op: AggOp::Sum,
    }]);
    sw
}

fn streams(children: usize, pairs: usize, seed: u64) -> Vec<Vec<KvPair>> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0x9E);
            (0..pairs)
                .map(|_| {
                    let id = child.gen_range_u64((pairs as u64 / 4).max(64));
                    KvPair::new(
                        Key::from_id(id, 16 + (id % 49) as usize),
                        child.gen_range_u64(100) as i64 - 50,
                    )
                })
                .collect()
        })
        .collect()
}

fn main() {
    let mut log = JsonLog::new();
    let children = 16usize;
    let pairs = 3_000usize;
    let cfg = TransportConfig::uniform(0.005, 0x919E);

    bench::section("batch schedule: legacy session vs pipeline driver (pinned identical)");
    log.push(&bench::run("legacy two-phase session", 1, 5, move || {
        let ss = streams(children, pairs, 0x919E);
        let mut sw = switch(children);
        let run = run_transport_scalar(&mut sw, TreeId(1), AggOp::Sum, &ss, &cfg);
        run.ingress.first_tx
            + run.ingress.retransmissions
            + run.egress.first_tx
            + run.egress.retransmissions
    }));
    log.push(&bench::run("pipeline driver, batch mode", 1, 5, move || {
        let ss = streams(children, pairs, 0x919E);
        let mut sw = switch(children);
        let run = run_pipeline_scalar(
            &mut sw,
            TreeId(1),
            AggOp::Sum,
            &ss,
            &PipelineConfig::batch(cfg),
        );
        run.ingress.first_tx
            + run.ingress.retransmissions
            + run.egress.first_tx
            + run.egress.retransmissions
    }));

    bench::section("overlapped relay (streaming egress during ingest)");
    log.push(&bench::run("pipeline driver, streaming", 1, 5, move || {
        let ss = streams(children, pairs, 0x919E);
        let mut sw = switch(children);
        let run = run_pipeline_scalar(
            &mut sw,
            TreeId(1),
            AggOp::Sum,
            &ss,
            &PipelineConfig::streaming(cfg),
        );
        run.ingress.first_tx
            + run.ingress.retransmissions
            + run.egress.first_tx
            + run.egress.retransmissions
    }));

    bench::section("two-level rack → spine → reducer composition");
    log.push(&bench::run("4x4 two-level streaming", 1, 5, move || {
        let racks = 4usize;
        let per = 4usize;
        let ss = streams(racks * per, pairs / 2, 0x919E);
        let grouped: Vec<Vec<Vec<KvPair>>> = ss.chunks(per).map(|c| c.to_vec()).collect();
        let mut rack_sw: Vec<SwitchAggSwitch> = (0..racks).map(|_| switch(per)).collect();
        let mut spine = switch(racks);
        let run = run_pipeline_two_level(
            &mut rack_sw,
            &mut spine,
            TreeId(1),
            AggOp::Sum,
            &grouped,
            &PipelineConfig::streaming(cfg),
        );
        run.ingress.first_tx
            + run.ingress.retransmissions
            + run.relay.first_tx
            + run.relay.retransmissions
            + run.egress.first_tx
            + run.egress.retransmissions
    }));

    let path = std::env::var("SWITCHAGG_BENCH_PIPELINE_JSON")
        .unwrap_or_else(|_| "BENCH_pipeline.json".to_string());
    if let Err(e) = log.write(&path) {
        eprintln!("could not write bench log {path}: {e}");
    }
}
