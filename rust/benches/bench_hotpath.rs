//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): the per-pair
//! switch loop, the hash unit, the FPE table probe (scalar + batched),
//! the software reducer (hash-map vs SoA table core), and the PJRT
//! execution path.  Results are also written as a machine-readable log
//! (`BENCH_hotpath.json`, override with `SWITCHAGG_BENCH_JSON`) so the
//! perf trajectory is comparable across PRs.

use switchagg::protocol::{AggOp, Key, KvPair, TreeConfig, TreeId};
use switchagg::runtime::AggEngine;
use switchagg::switch::hash::{fnv1a_key, fnv1a_words};
use switchagg::switch::hash_table::HashTable;
use switchagg::switch::{SwitchAggSwitch, SwitchConfig};
use switchagg::util::bench::{self, JsonLog};
use switchagg::util::rng::Pcg32;
use switchagg::workload::generator::{KeyDist, WorkloadSpec};

fn main() {
    let mut log = JsonLog::new();

    bench::section("hash unit");
    let keys: Vec<Key> = (0..4096u64).map(|i| Key::from_id(i, 16 + (i % 49) as usize)).collect();
    log.push(&bench::run("fnv1a_key 64B width", 3, 20, || {
        let mut acc = 0u32;
        for k in &keys {
            acc = acc.wrapping_add(fnv1a_key(k, 64));
        }
        std::hint::black_box(acc);
        keys.len() as u64
    }));
    let words: Vec<u32> = (0..16 * 4096).map(|i| i as u32).collect();
    log.push(&bench::run("fnv1a_words 16 words", 3, 20, || {
        let mut acc = 0u32;
        for row in words.chunks_exact(16) {
            acc = acc.wrapping_add(fnv1a_words(row));
        }
        std::hint::black_box(acc);
        (words.len() / 16) as u64
    }));

    bench::section("FPE hash-table probe");
    let mut rng = Pcg32::new(7);
    let probes: Vec<KvPair> = (0..100_000)
        .map(|_| KvPair::new(Key::from_id(rng.gen_range_u64(50_000), 16), 1))
        .collect();
    log.push(&bench::run("offer() 100k pairs, 64k-pair table", 2, 10, || {
        let mut t = HashTable::with_memory(64 * 1024 * 20, 16, 2);
        for p in &probes {
            std::hint::black_box(t.offer(p.key, p.value, AggOp::Sum, true));
        }
        probes.len() as u64
    }));
    log.push(&bench::run("offer_batch() 100k pairs, 64k-pair table", 2, 10, || {
        let mut t = HashTable::with_memory(64 * 1024 * 20, 16, 2);
        let mut evicted: Vec<(Key, switchagg::protocol::Value, u32)> = Vec::new();
        for chunk in probes.chunks(32) {
            evicted.clear();
            t.offer_batch(chunk, AggOp::Sum, true, &mut evicted);
            std::hint::black_box(evicted.len());
        }
        probes.len() as u64
    }));
    // Warm table built once, outside the timed region: the case
    // measures the probe path alone.
    let warm_table = {
        let mut t = HashTable::with_memory(64 * 1024 * 20, 16, 2);
        for p in &probes {
            t.offer(p.key, p.value, AggOp::Sum, true);
        }
        t
    };
    log.push(&bench::run("get_hashed() 100k probes, warm table", 2, 10, || {
        let mut hits = 0u64;
        for p in &probes {
            let h = warm_table.hash_of(&p.key);
            hits += warm_table.get_hashed(h, &p.key).is_some() as u64;
        }
        std::hint::black_box(hits);
        probes.len() as u64
    }));

    bench::section("whole-switch per-pair loop");
    let streams: Vec<Vec<KvPair>> = (0..3)
        .map(|i| {
            WorkloadSpec::paper(4 << 20, 1 << 20, KeyDist::Zipf(0.99), 0xBE + i).generate()
        })
        .collect();
    let total_pairs: u64 = streams.iter().map(|s| s.len() as u64).sum();
    log.push(&bench::run("switch ingest 12MB zipf (3 streams)", 1, 5, || {
        let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(32 << 10, Some(8 << 20)));
        let tree = TreeId(1);
        sw.configure(&[TreeConfig {
            tree,
            children: 3,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        sw.ingest_child_streams(tree, AggOp::Sum, &streams);
        total_pairs
    }));
    log.push(&bench::run("switch ingest 12MB zipf (reused engine)", 1, 5, {
        // Steady state: one switch, sinks and tables warm across reps —
        // the zero-alloc path the acceptance criteria target.
        let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(32 << 10, Some(8 << 20)));
        let tree = TreeId(1);
        sw.configure(&[TreeConfig {
            tree,
            children: 3,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        let streams = streams.clone();
        move || {
            sw.ingest_child_streams(tree, AggOp::Sum, &streams);
            total_pairs
        }
    }));

    bench::section("software reducer");
    let merged: Vec<KvPair> = streams.iter().flatten().copied().collect();
    log.push(&bench::run("hashmap merge", 1, 5, || {
        let r = switchagg::framework::Reducer::merge_software(
            std::slice::from_ref(&merged),
            AggOp::Sum,
        );
        std::hint::black_box(r.table.len());
        merged.len() as u64
    }));
    log.push(&bench::run("soa table-core merge", 1, 5, || {
        let r = switchagg::framework::Reducer::merge_table_core(
            std::slice::from_ref(&merged),
            AggOp::Sum,
        );
        std::hint::black_box(r.table.len());
        merged.len() as u64
    }));

    bench::section("PJRT runtime (AOT JAX/Pallas)");
    match AggEngine::discover() {
        Ok(engine) => {
            let table = vec![0f32; engine.table_size];
            let mut idx = vec![0i32; engine.batch_size];
            let mut vals = vec![0f32; engine.batch_size];
            let mut rng = Pcg32::new(3);
            for i in 0..engine.batch_size {
                idx[i] = rng.gen_range_u64(engine.table_size as u64) as i32;
                vals[i] = 1.0;
            }
            log.push(&bench::run("aggregate_f32 sum, 1024-pair batch", 1, 5, || {
                let out = engine.aggregate_f32(AggOp::Sum, &table, &idx, &vals).unwrap();
                std::hint::black_box(out[0]);
                engine.batch_size as u64
            }));
            let words = vec![0x1234_5678u32; engine.batch_size * engine.key_words];
            log.push(&bench::run("hash_keys 1024x16 words", 1, 5, || {
                let out = engine.hash_keys(&words).unwrap();
                std::hint::black_box(out[0]);
                engine.batch_size as u64
            }));
        }
        Err(e) => println!("PJRT bench skipped: {e:#}"),
    }

    let path = std::env::var("SWITCHAGG_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    if let Err(e) = log.write(&path) {
        eprintln!("could not write bench log {path}: {e}");
    }
}
