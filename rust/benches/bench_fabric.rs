//! Parallel-engine benchmarks (EXPERIMENTS.md §Fabric & NetSim): the
//! sharded switch ingest against the serial reference at 1/2/4/8
//! shards, the calendar-queue NetSim against the retained BinaryHeap
//! baseline, and the partitioned rack-scale tree runner.  Results are
//! written as a machine-readable log (`BENCH_fabric.json`, override
//! with `SWITCHAGG_BENCH_FABRIC_JSON`) so the perf trajectory is
//! comparable across PRs.

use switchagg::controller::AggTree;
use switchagg::net::netsim::reference::HeapNetSim;
use switchagg::net::partition::staggered_sends;
use switchagg::net::{run_monolithic, run_tree_partitioned, NetSim, NodeId, Topology};
use switchagg::protocol::{AggOp, KvPair, TreeConfig, TreeId};
use switchagg::switch::{Parallelism, SwitchAggSwitch, SwitchConfig};
use switchagg::util::bench::{self, JsonLog};
use switchagg::workload::generator::{KeyDist, WorkloadSpec};

fn fabric_switch(par: Parallelism) -> SwitchAggSwitch {
    let mut cfg = SwitchConfig::scaled(32 << 10, Some(8 << 20));
    cfg.parallelism = par;
    let mut sw = SwitchAggSwitch::new(cfg);
    sw.configure(&[TreeConfig {
        tree: TreeId(1),
        children: 3,
        parent_port: 0,
        op: AggOp::Sum,
    }]);
    sw
}

fn main() {
    let mut log = JsonLog::new();

    bench::section("sharded switch ingest (12MB zipf, 3 streams)");
    let streams: Vec<Vec<KvPair>> = (0..3)
        .map(|i| WorkloadSpec::paper(4 << 20, 1 << 20, KeyDist::Zipf(0.99), 0xFA_B0 + i).generate())
        .collect();
    let total_pairs: u64 = streams.iter().map(|s| s.len() as u64).sum();
    {
        let mut sw = fabric_switch(Parallelism::Serial);
        let streams = streams.clone();
        log.push(&bench::run("switch ingest 12MB zipf serial", 1, 5, move || {
            sw.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
            total_pairs
        }));
    }
    for shards in [1usize, 2, 4, 8] {
        let mut sw = fabric_switch(Parallelism::Sharded(shards));
        let streams = streams.clone();
        log.push(&bench::run(
            &format!("switch ingest 12MB zipf sharded x{shards}"),
            1,
            5,
            move || {
                sw.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
                total_pairs
            },
        ));
    }

    bench::section("NetSim event core (heap baseline vs calendar queue)");
    // A rack-scale incast: 31 mappers × 400 MTU packets over a 4×8
    // two-level topology; items = packet-hops (events).
    let (topo, _spine, _leaves, hosts) = Topology::two_level(4, 8);
    let reducer = *hosts.last().unwrap();
    let mappers: Vec<NodeId> = hosts[..hosts.len() - 1].to_vec();
    let sends = staggered_sends(&mappers, 400, 1500, 1.5e-6, 1e-8);
    let events = {
        let mut sim = NetSim::new(topo.clone());
        for s in &sends {
            sim.send(s.t, s.src, reducer, s.bytes);
        }
        sim.run();
        sim.events_processed()
    };
    {
        let topo = topo.clone();
        let sends = sends.clone();
        log.push(&bench::run("netsim heap baseline (events)", 1, 5, move || {
            let mut sim = HeapNetSim::new(topo.clone());
            for s in &sends {
                sim.send(s.t, s.src, reducer, s.bytes);
            }
            sim.run();
            sim.events_processed()
        }));
    }
    {
        let topo = topo.clone();
        let sends = sends.clone();
        log.push(&bench::run("netsim calendar queue (events)", 1, 5, move || {
            let mut sim = NetSim::new(topo.clone());
            for s in &sends {
                sim.send(s.t, s.src, reducer, s.bytes);
            }
            sim.run();
            sim.events_processed()
        }));
    }

    bench::section("partitioned tree runner (31-mapper rack)");
    let tree = AggTree::build(&topo, TreeId(1), AggOp::Sum, &mappers, reducer)
        .expect("rack tree builds");
    {
        let topo = topo.clone();
        let sends = sends.clone();
        log.push(&bench::run("tree sim monolithic", 1, 5, move || {
            let r = run_monolithic(&topo, reducer, &sends);
            std::hint::black_box(r.makespan_s);
            events
        }));
    }
    for shards in [1usize, 2, 4, 8] {
        let topo = topo.clone();
        let tree = tree.clone();
        let sends = sends.clone();
        log.push(&bench::run(
            &format!("tree sim partitioned x{shards}"),
            1,
            5,
            move || {
                let r = run_tree_partitioned(&topo, &tree, &sends, Parallelism::Sharded(shards));
                std::hint::black_box(r.makespan_s);
                events
            },
        ));
    }

    let path = std::env::var("SWITCHAGG_BENCH_FABRIC_JSON")
        .unwrap_or_else(|_| "BENCH_fabric.json".to_string());
    if let Err(e) = log.write(&path) {
        eprintln!("could not write bench log {path}: {e}");
    }
}
