//! Integrity-driver benchmarks (EXPERIMENTS.md §Integrity &
//! corruption): what end-to-end protection costs.  Three structural
//! claims under test: (1) the CRC-on zero-corruption session costs
//! only the trailer arithmetic over the legacy transport driver — the
//! clean-delivery path never decodes, so throughput tracks
//! `BENCH_transport.json`; (2) under wire corruption the cost is the
//! retransmitted packets plus one decode per flipped delivery, so
//! items/s degrades with the flip rate, not with a per-packet
//! verification tax; (3) the audit-recovery path (SRAM flip → scrub →
//! epoch-fenced re-run) is dominated by the replayed ingress, like a
//! crash restart.  Items = transport packets put on the wire (data
//! first-tx + retransmissions, both hops), comparable against
//! `BENCH_transport.json` and `BENCH_faults.json`.  Results land in
//! `BENCH_integrity.json` (override with
//! `SWITCHAGG_BENCH_INTEGRITY_JSON`).

use switchagg::framework::integrity::{run_integrity_scalar, IntegrityConfig};
use switchagg::framework::transport::{run_transport_scalar, TransportConfig};
use switchagg::net::FaultPlan;
use switchagg::protocol::{AggOp, Key, KvPair, TreeConfig, TreeId};
use switchagg::switch::{SwitchAggSwitch, SwitchConfig};
use switchagg::util::bench::{self, JsonLog};
use switchagg::util::rng::Pcg32;

fn streams(children: usize, pairs: usize, seed: u64) -> Vec<Vec<KvPair>> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0x1D);
            (0..pairs)
                .map(|_| {
                    let id = child.gen_range_u64((pairs as u64 / 4).max(64));
                    KvPair::new(
                        Key::from_id(id, 16 + (id % 49) as usize),
                        child.gen_range_u64(100) as i64 - 50,
                    )
                })
                .collect()
        })
        .collect()
}

fn switch() -> SwitchAggSwitch {
    let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(32 << 10, Some(8 << 20)));
    sw.configure(&[TreeConfig {
        tree: TreeId(1),
        children: 8,
        parent_port: 0,
        op: AggOp::Sum,
    }]);
    sw
}

fn wire_packets(
    ingress: &switchagg::framework::transport::NetHopStats,
    egress: &switchagg::framework::transport::NetHopStats,
) -> u64 {
    ingress.first_tx + ingress.retransmissions + egress.first_tx + egress.retransmissions
}

fn integrity_session(pairs: usize, cfg: &IntegrityConfig) -> u64 {
    let ss = streams(8, pairs, 0x1D7E);
    let mut sw = switch();
    let run = run_integrity_scalar(&mut sw, TreeId(1), AggOp::Sum, &ss, cfg);
    if cfg.crc {
        assert!(run.exact, "protected run diverged");
    }
    wire_packets(&run.ingress, &run.egress)
}

fn main() {
    let mut log = JsonLog::new();
    let pairs = 4_000usize;

    bench::section("zero-corruption overhead (CRC trailer vs legacy transport)");
    log.push(&bench::run("legacy transport 8x", 1, 5, move || {
        let ss = streams(8, pairs, 0x1D7E);
        let mut sw = switch();
        let run =
            run_transport_scalar(&mut sw, TreeId(1), AggOp::Sum, &ss, &TransportConfig::default());
        wire_packets(&run.ingress, &run.egress)
    }));
    let clean = IntegrityConfig::default();
    log.push(&bench::run("crc clean wire 8x", 1, 5, move || {
        integrity_session(pairs, &clean)
    }));

    bench::section("detection & recovery cost");
    let corrupt = IntegrityConfig::corrupting(1e-2, 0x1D7E);
    log.push(&bench::run("crc corrupt 1e-2 8x", 1, 5, move || {
        integrity_session(pairs, &corrupt)
    }));
    let legacy_corrupt = IntegrityConfig::corrupting(1e-2, 0x1D7E).with_crc(false);
    log.push(&bench::run("legacy corrupt 1e-2 8x", 1, 5, move || {
        integrity_session(pairs, &legacy_corrupt)
    }));
    let base_jct = {
        let ss = streams(8, pairs, 0x1D7E);
        let mut sw = switch();
        run_integrity_scalar(&mut sw, TreeId(1), AggOp::Sum, &ss, &IntegrityConfig::default())
            .jct_s
    };
    let sram = IntegrityConfig::default()
        .with_plan(FaultPlan::none().with_sram_flip(base_jct * 0.25, 0x1D7E));
    log.push(&bench::run("audit recovery (sram flip) 8x", 1, 5, move || {
        integrity_session(pairs, &sram)
    }));

    let path = std::env::var("SWITCHAGG_BENCH_INTEGRITY_JSON")
        .unwrap_or_else(|_| "BENCH_integrity.json".to_string());
    if let Err(e) = log.write(&path) {
        eprintln!("could not write bench log {path}: {e}");
    }
}
