//! Chaos-driver benchmarks (EXPERIMENTS.md §Fault tolerance &
//! failover): what the fault-injection machinery costs.  Three
//! structural claims under test: (1) the chaos wrapper's zero-fault
//! overhead over the plain transport driver is small — every fault
//! hook is a cheap predicate when the plan is empty; (2) crash
//! recovery's cost is dominated by the epoch replay (packets resent
//! from seq 1), so its items/s tracks the extra wire packets, not the
//! bookkeeping; (3) software failover pays the no-aggregation
//! serialization the paper's in-network path exists to avoid.  Items =
//! transport packets put on the wire (data first-tx + retransmissions,
//! both hops), so items/s is comparable across cases and against
//! `BENCH_transport.json`.  Results land in `BENCH_faults.json`
//! (override with `SWITCHAGG_BENCH_FAULTS_JSON`).

use switchagg::framework::chaos::{run_chaos_scalar, ChaosConfig};
use switchagg::framework::transport::run_transport_scalar;
use switchagg::net::FaultPlan;
use switchagg::protocol::{AggOp, Key, KvPair, TreeConfig, TreeId};
use switchagg::switch::{SwitchAggSwitch, SwitchConfig};
use switchagg::util::bench::{self, JsonLog};
use switchagg::util::rng::Pcg32;

fn streams(children: usize, pairs: usize, seed: u64) -> Vec<Vec<KvPair>> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0xFA);
            (0..pairs)
                .map(|_| {
                    let id = child.gen_range_u64((pairs as u64 / 4).max(64));
                    KvPair::new(
                        Key::from_id(id, 16 + (id % 49) as usize),
                        child.gen_range_u64(100) as i64 - 50,
                    )
                })
                .collect()
        })
        .collect()
}

fn switch_cfg() -> SwitchConfig {
    SwitchConfig::scaled(32 << 10, Some(8 << 20))
}

fn wire_packets(ingress: &switchagg::framework::transport::NetHopStats,
                egress: &switchagg::framework::transport::NetHopStats) -> u64 {
    ingress.first_tx + ingress.retransmissions + egress.first_tx + egress.retransmissions
}

fn plain_session(children: usize, pairs: usize) -> u64 {
    let ss = streams(children, pairs, 0xFA17);
    let mut sw = SwitchAggSwitch::new(switch_cfg());
    sw.configure(&[TreeConfig {
        tree: TreeId(1),
        children: children as u16,
        parent_port: 0,
        op: AggOp::Sum,
    }]);
    let cfg = ChaosConfig::default();
    let run = run_transport_scalar(&mut sw, TreeId(1), AggOp::Sum, &ss, &cfg.transport);
    wire_packets(&run.ingress, &run.egress)
}

fn chaos_session(children: usize, pairs: usize, cfg: &ChaosConfig) -> u64 {
    let ss = streams(children, pairs, 0xFA17);
    let run = run_chaos_scalar(&switch_cfg(), AggOp::Sum, &ss, cfg).expect("chaos session");
    wire_packets(&run.ingress, &run.egress)
}

fn main() {
    let mut log = JsonLog::new();
    let (children, pairs) = (8usize, 4_000usize);

    bench::section("zero-fault overhead (chaos wrapper vs plain transport)");
    log.push(&bench::run("plain transport 8x", 1, 5, move || {
        plain_session(children, pairs)
    }));
    let empty = ChaosConfig::default();
    log.push(&bench::run("chaos empty plan 8x", 1, 5, move || {
        chaos_session(children, pairs, &empty)
    }));

    bench::section("recovery & failover cost");
    // Crash/restart times are fractions of the fault-free JCT so the
    // bench exercises the same job phases at any machine speed.
    let base = {
        let ss = streams(children, pairs, 0xFA17);
        run_chaos_scalar(&switch_cfg(), AggOp::Sum, &ss, &ChaosConfig::default())
            .expect("baseline")
            .jct_s
    };
    let crash = ChaosConfig {
        plan: FaultPlan::none().with_switch_crash(base * 0.3, Some(base * 0.6)),
        ..ChaosConfig::default()
    };
    log.push(&bench::run("chaos crash+restart 8x", 1, 5, move || {
        chaos_session(children, pairs, &crash)
    }));
    let dead = ChaosConfig {
        plan: FaultPlan::none().with_switch_crash(base * 0.3, None),
        max_retries: Some(6),
        ..ChaosConfig::default()
    };
    log.push(&bench::run("chaos dead-switch failover 8x", 1, 5, move || {
        chaos_session(children, pairs, &dead)
    }));

    let path = std::env::var("SWITCHAGG_BENCH_FAULTS_JSON")
        .unwrap_or_else(|_| "BENCH_faults.json".to_string());
    if let Err(e) = log.write(&path) {
        eprintln!("could not write bench log {path}: {e}");
    }
}
