//! Bench + regeneration of Fig. 10 (job completion time).

use switchagg::experiments::{fig10, Scale};
use switchagg::util::bench;

fn main() {
    let scale = Scale::default();
    bench::section("Fig. 10 — job completion time");
    let rows = fig10::run(scale);
    fig10::print_rows(&rows, scale);
    bench::run("fig10 4 jobs w/ + w/o SwitchAgg", 1, 3, || {
        fig10::run(scale).iter().map(|r| r.report.input_pairs).sum()
    });
}
