//! Vector-value (W-lane) hot-path benchmarks (EXPERIMENTS.md
//! §Vector values & allreduce): lane-wise combine throughput across
//! widths, W-lane table ingest against its scalar-emulation
//! equivalent, the W = 1 scalar-regression guard, and the whole-switch
//! vector ingest on the 12 MB allreduce workload.  Results are also
//! written as a machine-readable log (`BENCH_vector.json`, override
//! with `SWITCHAGG_BENCH_VECTOR_JSON`) so the perf trajectory is
//! comparable across PRs.
//!
//! Acceptance gauge (ISSUE 3): the `W=64 ingest` case's lane-ops/s
//! should be ≥ 4× the `64 scalar offers` case's on the same run, and
//! the scalar guard case should sit within noise of
//! `BENCH_hotpath.json`'s `offer_batch` entry.

use switchagg::protocol::{AggOp, Key, KvPair, TreeConfig, TreeId, Value, VectorBatch};
use switchagg::switch::hash_table::{HashTable, VectorEvictSink};
use switchagg::switch::{SwitchAggSwitch, SwitchConfig, VectorSink};
use switchagg::util::bench::{self, JsonLog};
use switchagg::util::rng::Pcg32;
use switchagg::workload::allreduce::AllreduceSpec;

fn main() {
    let mut log = JsonLog::new();

    bench::section("lane-wise combine (AggOp::combine_slice)");
    const TOTAL_LANES: usize = 1 << 20;
    for &w in &[1usize, 8, 64, 256] {
        let rows = TOTAL_LANES / w;
        let mut acc: Vec<Value> = vec![1; rows * w];
        let src: Vec<Value> = vec![3; rows * w];
        log.push(&bench::run(
            &format!("combine_slice W={w} (1M lanes)"),
            3,
            20,
            || {
                for (a, b) in acc.chunks_exact_mut(w).zip(src.chunks_exact(w)) {
                    AggOp::Sum.combine_slice(a, b);
                }
                std::hint::black_box(acc[0]);
                (rows * w) as u64
            },
        ));
    }

    bench::section("W-lane table ingest vs scalar-emulation equivalent");
    const W: usize = 64;
    const ROWS: usize = 20_000;
    const VARIETY: u64 = 5_000;
    let mut rng = Pcg32::new(7);
    let mut batch = VectorBatch::with_capacity(W, ROWS);
    let mut lanes: Vec<Value> = vec![0; W];
    let mut ids: Vec<u64> = Vec::with_capacity(ROWS);
    for _ in 0..ROWS {
        let id = rng.gen_range_u64(VARIETY);
        ids.push(id);
        for (l, v) in lanes.iter_mut().enumerate() {
            *v = (id % 7) as i64 + l as i64;
        }
        batch.push(Key::from_id(id, 16), &lanes);
    }
    // The same logical work as 64 scalar pairs per row: key ⊕ lane id.
    let scalar_emulation: Vec<KvPair> = ids
        .iter()
        .flat_map(|&id| {
            (0..W as u64).map(move |l| {
                KvPair::new(Key::from_id(id * W as u64 + l, 16), (id % 7) as i64 + l as i64)
            })
        })
        .collect();
    // Both tables sized for the same slot count (the wide table's
    // slots are W lanes wide, the scalar one holds W× as many).
    let mut sink = VectorEvictSink::new();
    log.push(&bench::run("offer_lanes_batch W=64, 20k rows (lane-ops)", 2, 10, || {
        let mut t =
            HashTable::with_memory_lanes((8 * 1024 * (16 + W * 4)) as u64, 16, 2, W);
        sink.clear();
        t.offer_lanes_batch(&batch, AggOp::Sum, true, &mut sink);
        std::hint::black_box(sink.len());
        (batch.len() * W) as u64
    }));
    let mut evicted: Vec<(Key, Value, u32)> = Vec::new();
    log.push(&bench::run("64 scalar offers per row, 20k rows (lane-ops)", 2, 10, || {
        let mut t = HashTable::with_memory((8 * 1024 * W * 20) as u64, 16, 2);
        evicted.clear();
        t.offer_batch(&scalar_emulation, AggOp::Sum, true, &mut evicted);
        std::hint::black_box(evicted.len());
        scalar_emulation.len() as u64
    }));

    bench::section("scalar regression guard (same shape as bench_hotpath)");
    let mut rng = Pcg32::new(7);
    let probes: Vec<KvPair> = (0..100_000)
        .map(|_| KvPair::new(Key::from_id(rng.gen_range_u64(50_000), 16), 1))
        .collect();
    log.push(&bench::run(
        "offer_batch() 100k pairs, 64k-pair table (scalar guard)",
        2,
        10,
        || {
            let mut t = HashTable::with_memory(64 * 1024 * 20, 16, 2);
            let mut evicted: Vec<(Key, Value, u32)> = Vec::new();
            for chunk in probes.chunks(32) {
                evicted.clear();
                t.offer_batch(chunk, AggOp::Sum, true, &mut evicted);
                std::hint::black_box(evicted.len());
            }
            probes.len() as u64
        },
    ));
    let w1: VectorBatch = VectorBatch::from_pairs(&probes);
    log.push(&bench::run(
        "offer_lanes_batch W=1, 100k pairs (degenerate-case guard)",
        2,
        10,
        || {
            let mut t = HashTable::with_memory(64 * 1024 * 20, 16, 2);
            sink.clear();
            t.offer_lanes_batch(&w1, AggOp::Sum, true, &mut sink);
            std::hint::black_box(sink.len());
            w1.len() as u64
        },
    ));

    bench::section("whole-switch vector ingest (12MB allreduce, W=64)");
    // 3 workers x ~4 MB of 64-lane gradient chunks ≈ the 12 MB scalar
    // ingest case in bench_hotpath, but vector-valued.
    let per_worker_rows = (4 << 20) / (2 + 8 + 64 * 4);
    let spec = AllreduceSpec::dense(per_worker_rows * 64, 64, 3, 0xBEEF);
    let streams = spec.all_workers();
    let total_pairs: u64 = streams.iter().map(|s| s.len() as u64).sum();
    log.push(&bench::run("switch vector ingest 12MB allreduce W=64", 1, 5, {
        let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(32 << 10, Some(32 << 20)));
        let tree = TreeId(1);
        sw.configure_vector(
            &[TreeConfig {
                tree,
                children: 3,
                parent_port: 0,
                op: AggOp::Sum,
            }],
            64,
        );
        let mut sink = VectorSink::new(64);
        move || {
            sink.clear();
            sw.ingest_vector_child_streams_into(tree, &streams, &mut sink);
            std::hint::black_box(sink.forwarded.len() + sink.flushed.len());
            total_pairs
        }
    }));
    log.push(&bench::run(
        "switch vector ingest 12MB allreduce W=64 (lane-ops)",
        1,
        5,
        {
            let streams = spec.all_workers();
            let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(32 << 10, Some(32 << 20)));
            let tree = TreeId(2);
            sw.configure_vector(
                &[TreeConfig {
                    tree,
                    children: 3,
                    parent_port: 0,
                    op: AggOp::Sum,
                }],
                64,
            );
            let mut sink = VectorSink::new(64);
            move || {
                sink.clear();
                sw.ingest_vector_child_streams_into(tree, &streams, &mut sink);
                std::hint::black_box(sink.forwarded.len() + sink.flushed.len());
                total_pairs * 64
            }
        },
    ));

    let path = std::env::var("SWITCHAGG_BENCH_VECTOR_JSON")
        .unwrap_or_else(|_| "BENCH_vector.json".to_string());
    if let Err(e) = log.write(&path) {
        eprintln!("could not write bench log {path}: {e}");
    }
}
