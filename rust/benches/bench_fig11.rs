//! Bench + regeneration of Fig. 11 (reducer CPU utilization).

use switchagg::experiments::{fig11, Scale};
use switchagg::util::bench;

fn main() {
    let scale = Scale::default();
    bench::section("Fig. 11 — CPU utilization");
    let rows = fig11::run(scale);
    fig11::print_rows(&rows);
    bench::run("fig11 4 jobs", 1, 3, || fig11::run(scale).len() as u64);
}
