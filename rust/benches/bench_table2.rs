//! Bench + regeneration of Table 2 (FIFO-full time ratio).

use switchagg::experiments::{table2, Scale};
use switchagg::util::bench;

fn main() {
    let scale = Scale::default();
    bench::section("Table 2 — FIFO-full time ratio");
    let rows = table2::run(scale);
    table2::print_rows(&rows);
    table2::print_stressed(&table2::run_stressed(scale));
    bench::run("table2 sweep 2-16GB (scale 1/1024)", 1, 3, || {
        table2::run(scale).iter().map(|r| r.written).sum()
    });
}
