//! Bench + regeneration of Fig. 2 (reduction vs key variety; multi-hop).

use switchagg::experiments::{fig2, Scale};
use switchagg::util::bench;

fn main() {
    let scale = Scale::default();
    bench::section("Fig. 2(a) — reduction ratio vs key variety");
    let rows = fig2::fig2a(scale);
    fig2::print_fig2a(&rows);
    bench::run("fig2a sweep (scale 1/1024)", 1, 3, || {
        fig2::fig2a(scale).len() as u64
    });

    bench::section("Fig. 2(b) — multi-hop aggregation");
    let rows = fig2::fig2b(scale);
    fig2::print_fig2b(&rows);
    bench::run("fig2b hops 1-4 (scale 1/1024)", 1, 3, || {
        fig2::fig2b(scale).len() as u64
    });
}
