//! Bench + regeneration of Fig. 9 (reduction ratio vs workload size /
//! memory capacity, uniform + zipf, single- and multi-level).

use switchagg::experiments::{fig9, Scale};
use switchagg::util::bench;
use switchagg::workload::generator::KeyDist;

fn main() {
    let scale = Scale::default();
    bench::section("Fig. 9 — reduction ratio grid");
    let rows = fig9::run(scale);
    fig9::print_rows(&rows);
    // Time one representative cell (16GB zipf multi-level); items =
    // approximate pairs simulated per rep.
    let pairs = scale.bytes(16 << 30) / 46;
    bench::run("fig9 cell 16GB zipf M-32MB", 1, 3, move || {
        let r = fig9::run_cell(scale, 16, 32 << 20, Some(8u64 << 30), KeyDist::Zipf(0.99));
        assert!(r > 0.0);
        pairs
    });
}
