//! Determinism contracts of the parallel execution engine:
//!
//! * **Shard-count invariance** — the group-sharded switch ingest at
//!   1/2/4/8 shards produces the *byte-identical* output stream,
//!   drained table state (the end-of-tree flush) and stats as the
//!   serial reference, across random seeds, key widths, eviction
//!   policies, child counts, and hierarchy on/off.
//! * **Calendar vs heap NetSim** — the calendar-queue event core
//!   matches the retained `BinaryHeap` implementation exactly
//!   (delivery times, per-link stats, delivery order) on random tree
//!   topologies.
//! * **Partitioned vs monolithic tree sims** — the per-subtree worker
//!   engine reproduces the monolithic run's aggregates.
//! * **Mid-stream-flush fallback** — chunk sequences the sharded
//!   engine cannot take still produce serial-identical results.

use switchagg::net::netsim::reference::HeapNetSim;
use switchagg::net::{run_monolithic, run_tree_partitioned, NetSim, NodeId, NodeKind, SendReq, Topology};
use switchagg::controller::AggTree;
use switchagg::protocol::{AggOp, Key, KvPair, TreeConfig, TreeId, VectorBatch};
use switchagg::sim::Link;
use switchagg::switch::{EvictionPolicy, Parallelism, SwitchAggSwitch, SwitchConfig};
use switchagg::util::miniprop::prop;
use switchagg::util::rng::Pcg32;

fn random_pairs(rng: &mut Pcg32, n: usize, variety: u64) -> Vec<KvPair> {
    (0..n)
        .map(|_| {
            let id = rng.gen_range_u64(variety);
            let len = 8 + (rng.gen_range_u64(57) as usize);
            KvPair::new(Key::from_id(id, len), rng.gen_range_u64(1000) as i64 - 500)
        })
        .collect()
}

fn switch(fpe: u64, bpe: Option<u64>, eviction: EvictionPolicy, children: u16, par: Parallelism) -> SwitchAggSwitch {
    let cfg = SwitchConfig {
        eviction,
        parallelism: par,
        ..SwitchConfig::scaled(fpe, bpe)
    };
    let mut sw = SwitchAggSwitch::new(cfg);
    sw.configure(&[TreeConfig {
        tree: TreeId(1),
        children,
        parent_port: 0,
        op: AggOp::Sum,
    }]);
    sw
}

fn stats_tuple(sw: &SwitchAggSwitch) -> String {
    format!("{:?}", sw.stats(TreeId(1)).unwrap())
}

#[test]
fn prop_sharded_ingest_is_shard_count_invariant() {
    // ISSUE 2 determinism satellite: identical drained table state and
    // eviction stream to serial ingest across seeds, key widths, and
    // eviction policies, at 1/2/4/8 shards.
    prop("sharded ingest == serial ingest", 12, |rng| {
        let fpe = 4096u64 << rng.gen_range_usize(4); // 4K..32K
        let bpe = if rng.gen_bool(0.7) {
            Some(1u64 << (16 + rng.gen_range_usize(5)))
        } else {
            None
        };
        let eviction = if rng.gen_bool(0.5) {
            EvictionPolicy::EvictOld
        } else {
            EvictionPolicy::ForwardNew
        };
        let children = 1 + rng.gen_range_u64(3) as u16;
        let variety = 1 << (6 + rng.gen_range_usize(8));
        let streams: Vec<Vec<KvPair>> = (0..children as usize)
            .map(|_| {
                let n = 500 + rng.gen_range_usize(3_000);
                random_pairs(rng, n, variety)
            })
            .collect();

        let mut serial = switch(fpe, bpe, eviction, children, Parallelism::Serial);
        let out_serial = serial.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
        let serial_stats = stats_tuple(&serial);

        for shards in [1usize, 2, 4, 8] {
            let mut sharded =
                switch(fpe, bpe, eviction, children, Parallelism::Sharded(shards));
            let out_sharded = sharded.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
            if out_sharded != out_serial {
                return Err(format!(
                    "output diverged at {shards} shards (fpe={fpe} bpe={bpe:?} \
                     eviction={eviction:?} children={children}): {} vs {} pairs",
                    out_sharded.len(),
                    out_serial.len()
                ));
            }
            let sharded_stats = stats_tuple(&sharded);
            if sharded_stats != serial_stats {
                return Err(format!(
                    "stats diverged at {shards} shards:\n  sharded {sharded_stats}\n  \
                     serial  {serial_stats}"
                ));
            }
            if serial.bpe_dram_stats(TreeId(1)) != sharded.bpe_dram_stats(TreeId(1)) {
                return Err(format!("DRAM stats diverged at {shards} shards"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_vector_w1_path_matches_scalar_across_shards() {
    // ISSUE 3 satellite: the degenerate 1-lane vector path must be
    // byte-identical — outputs, stats, DRAM counters — to the scalar
    // path, and therefore to the sharded scalar engine at 1/2/4/8
    // shards (which is itself pinned to serial above).  The vector
    // ingest always runs the serial reference engine; the shard sweep
    // runs on the scalar side.
    prop("vector W=1 ingest == scalar ingest", 10, |rng| {
        let fpe = 4096u64 << rng.gen_range_usize(4);
        let bpe = if rng.gen_bool(0.7) {
            Some(1u64 << (16 + rng.gen_range_usize(5)))
        } else {
            None
        };
        let eviction = if rng.gen_bool(0.5) {
            EvictionPolicy::EvictOld
        } else {
            EvictionPolicy::ForwardNew
        };
        let children = 1 + rng.gen_range_u64(3) as u16;
        let variety = 1 << (6 + rng.gen_range_usize(8));
        let streams: Vec<Vec<KvPair>> = (0..children as usize)
            .map(|_| {
                let n = 500 + rng.gen_range_usize(2_000);
                random_pairs(rng, n, variety)
            })
            .collect();
        let vstreams: Vec<VectorBatch> =
            streams.iter().map(|s| VectorBatch::from_pairs(s)).collect();

        let mut vector = {
            let cfg = SwitchConfig {
                eviction,
                ..SwitchConfig::scaled(fpe, bpe)
            };
            let mut sw = SwitchAggSwitch::new(cfg);
            sw.configure_vector(
                &[TreeConfig {
                    tree: TreeId(1),
                    children,
                    parent_port: 0,
                    op: AggOp::Sum,
                }],
                1,
            );
            sw
        };
        let out_vector = vector
            .ingest_vector_child_streams(TreeId(1), &vstreams)
            .to_pairs();
        let vector_stats = stats_tuple(&vector);

        for shards in [1usize, 2, 4, 8] {
            let par = if shards == 1 {
                Parallelism::Serial
            } else {
                Parallelism::Sharded(shards)
            };
            let mut scalar = switch(fpe, bpe, eviction, children, par);
            let out_scalar = scalar.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
            if out_scalar != out_vector {
                return Err(format!(
                    "vector W=1 output diverged from scalar at {shards} shards \
                     (fpe={fpe} bpe={bpe:?} eviction={eviction:?} children={children}): \
                     {} vs {} pairs",
                    out_vector.len(),
                    out_scalar.len()
                ));
            }
            let scalar_stats = stats_tuple(&scalar);
            if scalar_stats != vector_stats {
                return Err(format!(
                    "vector W=1 stats diverged at {shards} shards:\n  vector {vector_stats}\n  \
                     scalar {scalar_stats}"
                ));
            }
            if scalar.bpe_dram_stats(TreeId(1)) != vector.bpe_dram_stats(TreeId(1)) {
                return Err(format!("DRAM stats diverged at {shards} shards"));
            }
        }
        Ok(())
    });
}

#[test]
fn sharded_fallback_on_mid_stream_flush_matches_serial() {
    // children=1 with 3 streams: the first stream's EoT flushes
    // mid-sequence, so the sharded engine must fall back — recording
    // the fallback in its stats — and still match the serial
    // reference exactly everywhere else.
    let mut rng = Pcg32::new(0xFA11BACC);
    let streams: Vec<Vec<KvPair>> = (0..3).map(|_| random_pairs(&mut rng, 1500, 300)).collect();
    let mut serial = switch(8 << 10, Some(128 << 10), EvictionPolicy::EvictOld, 1, Parallelism::Serial);
    let out_serial = serial.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
    let mut sharded = switch(8 << 10, Some(128 << 10), EvictionPolicy::EvictOld, 1, Parallelism::Sharded(4));
    let out_sharded = sharded.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
    assert_eq!(out_sharded, out_serial);
    // The fallback is no longer silent: the sharded run counts it, the
    // serial reference does not.
    let s_serial = serial.stats(TreeId(1)).unwrap();
    let s_sharded = sharded.stats(TreeId(1)).unwrap();
    assert!(s_sharded.fallback_serial > 0, "fallback must be recorded");
    assert_eq!(s_serial.fallback_serial, 0);
    // Everything else stays byte-identical.
    let mut a = s_serial.clone();
    let mut b = s_sharded.clone();
    a.fallback_serial = 0;
    b.fallback_serial = 0;
    assert_eq!(format!("{a:?}"), format!("{b:?}"));
}

/// Random tree topology: switches in a random-arity tree, hosts hung
/// off random switches.  Tree ⇒ unique shortest paths ⇒ the
/// partitioned runner is exactly comparable to the monolithic sim.
fn random_tree_topo(rng: &mut Pcg32) -> (Topology, Vec<NodeId>, Vec<NodeId>) {
    let mut topo = Topology::new(Link::ten_gbe());
    let n_switches = 1 + rng.gen_range_usize(6);
    let mut switches = vec![topo.add_node(NodeKind::Switch)];
    for _ in 1..n_switches {
        let parent = switches[rng.gen_range_usize(switches.len())];
        let sw = topo.add_node(NodeKind::Switch);
        topo.connect(parent, sw);
        switches.push(sw);
    }
    let n_hosts = 2 + rng.gen_range_usize(10);
    let hosts: Vec<NodeId> = (0..n_hosts)
        .map(|_| {
            let sw = switches[rng.gen_range_usize(switches.len())];
            let h = topo.add_node(NodeKind::Host);
            topo.connect(sw, h);
            h
        })
        .collect();
    (topo, switches, hosts)
}

#[test]
fn prop_calendar_netsim_matches_heap_reference() {
    // ISSUE 2 differential satellite: pin the calendar-queue NetSim's
    // delivery times and LinkStats to the BinaryHeap implementation on
    // random topologies.
    prop("calendar NetSim == heap NetSim", 30, |rng| {
        let (mut topo, switches, hosts) = random_tree_topo(rng);
        // Sprinkle redundant switch-switch links so some cases have
        // equal-cost multipaths: the engines must still agree packet
        // for packet (routing is a pure function of (node, dst) in
        // both, cached vs recomputed).
        for _ in 0..rng.gen_range_usize(3) {
            let a = switches[rng.gen_range_usize(switches.len())];
            let b = switches[rng.gen_range_usize(switches.len())];
            if a != b {
                topo.connect(a, b);
            }
        }
        let mut cal = NetSim::new(topo.clone());
        let mut heap = HeapNetSim::new(topo);
        let sends = 50 + rng.gen_range_usize(400);
        for _ in 0..sends {
            let src = hosts[rng.gen_range_usize(hosts.len())];
            let dst = hosts[rng.gen_range_usize(hosts.len())];
            // Mostly sub-millisecond sends, but ~5% land seconds out —
            // far beyond one bucket-ring rotation (~0.5 ms), so head
            // times that wrap the calendar ring (and the jump-to-
            // earliest-slot path) are exercised every case.
            let t = if rng.gen_bool(0.05) {
                1.0 + rng.gen_range_u64(10_000) as f64 * 1e-3
            } else {
                rng.gen_range_u64(1_000) as f64 * 1e-6
            };
            let bytes = 1 + rng.gen_range_u64(100_000);
            cal.send(t, src, dst, bytes);
            heap.send(t, src, dst, bytes);
        }
        let (t_cal, t_heap) = (cal.run(), heap.run());
        if t_cal != t_heap {
            return Err(format!("makespan {t_cal} != {t_heap}"));
        }
        if cal.delivered() != heap.delivered() {
            return Err("delivery streams diverged".into());
        }
        if cal.link_stats() != heap.link_stats() {
            return Err("link stats diverged".into());
        }
        if cal.events_processed() != heap.events_processed() {
            return Err("event counts diverged".into());
        }
        Ok(())
    });
}

#[test]
fn prop_partitioned_tree_sim_matches_monolithic() {
    prop("partitioned tree sim == monolithic", 15, |rng| {
        let (topo, _switches, hosts) = random_tree_topo(rng);
        if hosts.len() < 3 {
            return Ok(());
        }
        let reducer = hosts[hosts.len() - 1];
        let mappers: Vec<NodeId> = hosts[..hosts.len() - 1].to_vec();
        let Ok(tree) = AggTree::build(&topo, TreeId(1), AggOp::Sum, &mappers, reducer) else {
            return Ok(()); // degenerate placement (e.g. reducer-only switch)
        };
        // Uniform packet size within a case (random across cases):
        // exact-time ties between equal-size packets are order-robust
        // down to the float ulp, so the aggregate comparison is exact.
        let bytes = 200 + rng.gen_range_u64(1300);
        let mut sends = Vec::new();
        for (i, &m) in mappers.iter().enumerate() {
            let n = 5 + rng.gen_range_usize(60);
            for k in 0..n {
                sends.push(SendReq {
                    t: k as f64 * 2e-6 + i as f64 * 1e-8,
                    src: m,
                    bytes,
                });
            }
        }
        let mono = run_monolithic(&topo, reducer, &sends);
        for par in [Parallelism::Serial, Parallelism::Sharded(4)] {
            let part = run_tree_partitioned(&topo, &tree, &sends, par);
            if part != mono {
                return Err(format!(
                    "partitioned ({par:?}) diverged: makespan {} vs {}, max link {} vs {}",
                    part.makespan_s, mono.makespan_s, part.max_link_bytes, mono.max_link_bytes
                ));
            }
        }
        Ok(())
    });
}
