//! Streaming pipeline — differential contracts:
//!
//! * **Zero-pipelining differential** — with overlap disabled, the
//!   unified pipeline driver (`framework::pipeline`) is the legacy
//!   two-phase transport session *byte for byte*: received stream,
//!   every ingress/egress hop counter, dedup stats, JCT, and FIFO
//!   peak, on the scalar and W-lane vector (W ∈ {1, 8}) paths, serial
//!   and sharded engines, lossless and lossy.  One driver, two
//!   schedules — the batch schedule is a configuration, not a fork.
//! * **Overlap invariants** — enabling overlap changes timing only:
//!   same aggregate, never a later JCT than batch at meaningful
//!   fan-in, and the two-level relay composition preserves the
//!   aggregate end to end.

use std::collections::HashMap;
use switchagg::framework::transport::{
    run_transport_scalar, run_transport_vector, TransportConfig,
};
use switchagg::framework::{
    run_pipeline_scalar, run_pipeline_two_level, run_pipeline_vector, PipelineConfig, Reducer,
};
use switchagg::protocol::{AggOp, Key, KvPair, TreeConfig, TreeId, Value, VectorBatch};
use switchagg::switch::{Parallelism, SwitchAggSwitch, SwitchConfig};
use switchagg::util::rng::Pcg32;

fn scalar_switch(children: u16, par: Parallelism) -> SwitchAggSwitch {
    let cfg = SwitchConfig {
        parallelism: par,
        ..SwitchConfig::scaled(16 << 10, Some(256 << 10))
    };
    let mut sw = SwitchAggSwitch::new(cfg);
    sw.configure(&[TreeConfig {
        tree: TreeId(1),
        children,
        parent_port: 0,
        op: AggOp::Sum,
    }]);
    sw
}

fn vector_switch(children: u16, lanes: usize, par: Parallelism) -> SwitchAggSwitch {
    let cfg = SwitchConfig {
        parallelism: par,
        ..SwitchConfig::scaled(32 << 10, Some(512 << 10))
    };
    let mut sw = SwitchAggSwitch::new(cfg);
    sw.configure_vector(
        &[TreeConfig {
            tree: TreeId(1),
            children,
            parent_port: 0,
            op: AggOp::Sum,
        }],
        lanes,
    );
    sw
}

fn scalar_streams(children: usize, n: usize, seed: u64) -> Vec<Vec<KvPair>> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0x99);
            (0..n)
                .map(|_| {
                    let id = child.gen_range_u64(400);
                    KvPair::new(
                        Key::from_id(id, 16 + (id % 49) as usize),
                        child.gen_range_u64(200) as i64 - 100,
                    )
                })
                .collect()
        })
        .collect()
}

fn vector_streams(children: usize, n: usize, lanes: usize, seed: u64) -> Vec<VectorBatch> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0xAA);
            let mut b = VectorBatch::new(lanes);
            let mut vals: Vec<Value> = vec![0; lanes];
            for _ in 0..n {
                let id = child.gen_range_u64(300);
                for (l, v) in vals.iter_mut().enumerate() {
                    *v = (id % 11) as i64 + l as i64 - 5;
                }
                b.push(Key::from_id(id, 16 + (id % 49) as usize), &vals);
            }
            b
        })
        .collect()
}

fn merged(pairs: &[KvPair]) -> HashMap<Key, Value> {
    Reducer::merge_software(&[pairs.to_vec()], AggOp::Sum).table
}

/// With overlap disabled, every observable of the pipelined session
/// must equal the legacy two-phase session's — not just the aggregate:
/// the wire schedule (bytes, retransmissions, timeouts), the switch
/// counters, and the clock.
#[test]
fn batch_pipeline_is_byte_identical_to_legacy_scalar() {
    for par in [Parallelism::Serial, Parallelism::Sharded(4)] {
        for cfg in [
            TransportConfig::default(),
            TransportConfig::uniform(0.05, 0x5EED).with_dup(0.02),
        ] {
            let ss = scalar_streams(4, 1_200, 13);
            let mut legacy_sw = scalar_switch(4, par);
            let legacy = run_transport_scalar(&mut legacy_sw, TreeId(1), AggOp::Sum, &ss, &cfg);
            let mut piped_sw = scalar_switch(4, par);
            let piped = run_pipeline_scalar(
                &mut piped_sw,
                TreeId(1),
                AggOp::Sum,
                &ss,
                &PipelineConfig::batch(cfg),
            );
            assert_eq!(piped.ingress, legacy.ingress, "{par:?}");
            assert_eq!(piped.egress, legacy.egress, "{par:?}");
            assert_eq!(piped.dedup, legacy.dedup, "{par:?}");
            assert_eq!(piped.completeness, legacy.completeness, "{par:?}");
            assert_eq!(piped.received, legacy.received, "{par:?}");
            assert_eq!(piped.jct_s, legacy.jct_s, "{par:?}");
            assert_eq!(piped.fifo_peak, legacy.fifo_peak, "{par:?}");
        }
    }
}

#[test]
fn batch_pipeline_is_byte_identical_to_legacy_vector() {
    for lanes in [1usize, 8] {
        for par in [Parallelism::Serial, Parallelism::Sharded(2)] {
            let ss = vector_streams(3, 700, lanes, 23);
            let cfg = TransportConfig::uniform(0.02, 0xFEED);
            let mut legacy_sw = vector_switch(3, lanes, par);
            let legacy = run_transport_vector(&mut legacy_sw, TreeId(1), AggOp::Sum, &ss, &cfg);
            let mut piped_sw = vector_switch(3, lanes, par);
            let piped = run_pipeline_vector(
                &mut piped_sw,
                TreeId(1),
                AggOp::Sum,
                &ss,
                &PipelineConfig::batch(cfg),
            );
            assert_eq!(piped.ingress, legacy.ingress, "W={lanes} {par:?}");
            assert_eq!(piped.egress, legacy.egress, "W={lanes} {par:?}");
            assert_eq!(piped.dedup, legacy.dedup, "W={lanes} {par:?}");
            assert_eq!(piped.completeness, legacy.completeness, "W={lanes} {par:?}");
            assert_eq!(piped.received, legacy.received, "W={lanes} {par:?}");
            assert_eq!(piped.jct_s, legacy.jct_s, "W={lanes} {par:?}");
            assert_eq!(piped.fifo_peak, legacy.fifo_peak, "W={lanes} {par:?}");
        }
    }
}

/// Overlap changes timing, never content: the streamed session's
/// aggregate equals batch's, and with enough fan-in its JCT is
/// strictly earlier (the eviction stream drains during ingest).
#[test]
fn overlap_preserves_aggregate_and_never_slows_the_job() {
    let ss = scalar_streams(8, 1_000, 31);
    let cfg = TransportConfig::default();
    let mut sw_b = scalar_switch(8, Parallelism::Serial);
    let batch = run_pipeline_scalar(
        &mut sw_b,
        TreeId(1),
        AggOp::Sum,
        &ss,
        &PipelineConfig::batch(cfg),
    );
    let mut sw_s = scalar_switch(8, Parallelism::Serial);
    let stream = run_pipeline_scalar(
        &mut sw_s,
        TreeId(1),
        AggOp::Sum,
        &ss,
        &PipelineConfig::streaming(cfg),
    );
    assert_eq!(merged(&stream.received), merged(&batch.received));
    assert!(stream.completeness.is_complete());
    assert!(
        stream.jct_s < batch.jct_s,
        "overlap must finish earlier: {} vs {}",
        stream.jct_s,
        batch.jct_s
    );
    // Same egress payload either way — overlap moves bytes earlier,
    // it does not invent or drop them (lossless ⇒ no retx inflation).
    assert_eq!(stream.egress.first_tx_bytes, batch.egress.first_tx_bytes);
}

/// Vector overlap: same invariants on the W-lane path.
#[test]
fn vector_overlap_preserves_aggregate() {
    let ss = vector_streams(4, 800, 8, 41);
    let cfg = TransportConfig::default();
    let mut sw_b = vector_switch(4, 8, Parallelism::Serial);
    let batch = run_pipeline_vector(
        &mut sw_b,
        TreeId(1),
        AggOp::Sum,
        &ss,
        &PipelineConfig::batch(cfg),
    );
    let mut sw_s = vector_switch(4, 8, Parallelism::Serial);
    let stream = run_pipeline_vector(
        &mut sw_s,
        TreeId(1),
        AggOp::Sum,
        &ss,
        &PipelineConfig::streaming(cfg),
    );
    assert!(stream.completeness.is_complete());
    assert!(stream.jct_s <= batch.jct_s);
    // Order can differ between schedules only if the switch emitted
    // differently — it must not: same ingest order, same evictions.
    assert_eq!(stream.received, batch.received);
}

/// The two-level relay under loss: rack → spine → reducer, all hops
/// overlapped, aggregate byte-exact against the software merge of all
/// mapper streams.
#[test]
fn two_level_relay_is_exact_under_loss() {
    let racks = 3;
    let per = 3;
    let ss = scalar_streams(racks * per, 600, 53);
    let grouped: Vec<Vec<Vec<KvPair>>> = ss.chunks(per).map(|c| c.to_vec()).collect();
    let mut rack_sw: Vec<SwitchAggSwitch> = (0..racks)
        .map(|_| scalar_switch(per as u16, Parallelism::Serial))
        .collect();
    let mut spine = scalar_switch(racks as u16, Parallelism::Serial);
    let run = run_pipeline_two_level(
        &mut rack_sw,
        &mut spine,
        TreeId(1),
        AggOp::Sum,
        &grouped,
        &PipelineConfig::streaming(TransportConfig::uniform(0.02, 0xBAD5)),
    );
    assert!(run.completeness.is_complete());
    let oracle = Reducer::merge_software(&ss, AggOp::Sum).table;
    assert_eq!(merged(&run.received), oracle);
    assert!(run.jct_s > 0.0);
    assert!(
        run.ingress.events > 0 && run.relay.first_tx_bytes > 0 && run.egress.first_tx_bytes > 0,
        "all three hops must carry traffic: {run:?}"
    );
}
