//! Integration tests over the assembled switch data plane: wire-format
//! round trips through the device, multi-level behaviour, multi-hop
//! chains and the DAIET baseline comparison.

use std::collections::HashMap;
use switchagg::baseline::{DaietConfig, DaietSwitch};
use switchagg::protocol::{AggOp, AggregationPacket, Key, KvPair, Packet, TreeConfig, TreeId};
use switchagg::switch::{SwitchAggSwitch, SwitchConfig};
use switchagg::util::rng::Pcg32;
use switchagg::workload::generator::{KeyDist, WorkloadSpec};

fn configured(fpe: u64, bpe: Option<u64>, children: u16) -> SwitchAggSwitch {
    let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(fpe, bpe));
    sw.configure(&[TreeConfig {
        tree: TreeId(1),
        children,
        parent_port: 0,
        op: AggOp::Sum,
    }]);
    sw
}

fn software_truth(streams: &[Vec<KvPair>]) -> HashMap<Key, i64> {
    let mut t = HashMap::new();
    for p in streams.iter().flatten() {
        *t.entry(p.key).or_insert(0) += p.value;
    }
    t
}

#[test]
fn switch_output_plus_nothing_equals_truth() {
    // Whatever leaves the switch (stream + flush), re-aggregated in
    // software, must equal the ground truth exactly — for every op.
    let mut rng = Pcg32::new(10);
    let streams: Vec<Vec<KvPair>> = (0..3)
        .map(|_| {
            (0..5_000)
                .map(|_| {
                    KvPair::new(
                        Key::from_id(rng.gen_range_u64(800), 16 + (rng.gen_range_u64(49)) as usize),
                        rng.gen_range_u64(100) as i64 - 50,
                    )
                })
                .collect()
        })
        .collect();
    let truth = software_truth(&streams);

    let mut sw = configured(32 << 10, Some(1 << 20), 3);
    let out = sw.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
    let mut got = HashMap::new();
    for p in &out {
        *got.entry(p.key).or_insert(0) += p.value;
    }
    assert_eq!(got, truth);
}

#[test]
fn max_and_min_survive_the_data_plane() {
    let mut rng = Pcg32::new(11);
    let stream: Vec<KvPair> = (0..20_000)
        .map(|_| {
            KvPair::new(
                Key::from_id(rng.gen_range_u64(500), 24),
                rng.gen_range_u64(10_000) as i64 - 5_000,
            )
        })
        .collect();
    for op in [AggOp::Max, AggOp::Min] {
        let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(16 << 10, Some(1 << 20)));
        sw.configure(&[TreeConfig {
            tree: TreeId(1),
            children: 1,
            parent_port: 0,
            op,
        }]);
        let out = sw.ingest_stream(TreeId(1), op, &stream);
        let mut got: HashMap<Key, i64> = HashMap::new();
        for p in &out {
            got.entry(p.key)
                .and_modify(|v| *v = op.combine(*v, p.value))
                .or_insert(p.value);
        }
        let mut want: HashMap<Key, i64> = HashMap::new();
        for p in &stream {
            want.entry(p.key)
                .and_modify(|v| *v = op.combine(*v, p.value))
                .or_insert(p.value);
        }
        assert_eq!(got, want, "{op}");
    }
}

#[test]
fn wire_format_round_trip_through_switch() {
    // Encode → decode → ingest: the data plane consumes exactly what
    // the protocol layer produced.
    let spec = WorkloadSpec::paper(64 << 10, 16 << 10, KeyDist::Uniform, 5);
    let pairs = spec.generate();
    let pkts = AggregationPacket::pack_stream(TreeId(1), AggOp::Sum, &pairs, true);
    let mut sw = configured(1 << 20, Some(4 << 20), 1);
    let mut out = Vec::new();
    for pkt in &pkts {
        // Serialize over the wire and back.
        let bytes = Packet::Aggregation(pkt.clone()).encode();
        let Packet::Aggregation(decoded) = Packet::decode(&bytes).unwrap() else {
            panic!("wrong packet type");
        };
        assert_eq!(&decoded, pkt);
        let r = sw.ingest(&decoded);
        out.extend(r.forwarded);
        if let Some(f) = r.flushed {
            out.extend(f);
        }
    }
    sw.finalize(TreeId(1));
    let got: i64 = out.iter().map(|p| p.value).sum();
    assert_eq!(got, pairs.len() as i64);
}

#[test]
fn chained_switches_multi_hop() {
    // Fig 2(b) with the real data plane: two switches in a streamline.
    let spec = WorkloadSpec::paper(512 << 10, 256 << 10, KeyDist::Uniform, 6);
    let stream = spec.generate();
    let mut sw1 = configured(16 << 10, None, 1);
    let mid = sw1.ingest_stream(TreeId(1), AggOp::Sum, &stream);
    let mut sw2 = configured(16 << 10, None, 1);
    let out = sw2.ingest_stream(TreeId(1), AggOp::Sum, &mid);
    // Conservation through two hops.
    assert_eq!(
        out.iter().map(|p| p.value).sum::<i64>(),
        stream.len() as i64
    );
    // Second hop adds some aggregation but bounded (Theorem 2.2).
    assert!(out.len() <= mid.len());
    let r1 = 1.0 - mid.len() as f64 / stream.len() as f64;
    let r2 = 1.0 - out.len() as f64 / stream.len() as f64;
    assert!(r2 >= r1 - 1e-9);
}

#[test]
fn switchagg_beats_daiet_on_large_variety() {
    // §2.2 / §8: DAIET's 16K-entry table collapses where SwitchAgg's
    // two-level hierarchy holds.
    let spec = WorkloadSpec {
        total_bytes: 2 << 20,
        key_variety: 60_000,
        key_len_min: 16,
        key_len_max: 16, // DAIET's fixed slot, to be charitable
        dist: KeyDist::Uniform,
        seed: 9,
    };
    let stream = spec.generate();

    let mut daiet = DaietSwitch::new(DaietConfig::default());
    daiet.run(&stream, AggOp::Sum);

    let mut sa = configured(32 << 10, Some(8 << 20), 1);
    sa.ingest_stream(TreeId(1), AggOp::Sum, &stream);
    let sa_r = sa.stats(TreeId(1)).unwrap().reduction_ratio();
    let daiet_r = daiet.stats.reduction_ratio();
    assert!(
        sa_r > daiet_r + 0.2,
        "SwitchAgg {sa_r:.3} should clearly beat DAIET {daiet_r:.3}"
    );
}

#[test]
fn reconfiguration_resets_engines() {
    let mut sw = configured(32 << 10, None, 1);
    let spec = WorkloadSpec::paper(128 << 10, 32 << 10, KeyDist::Uniform, 3);
    sw.ingest_stream(TreeId(1), AggOp::Sum, &spec.generate());
    let r1 = sw.stats(TreeId(1)).unwrap().reduction_ratio();
    // Adding a second tree rebuilds engines with half the memory.
    sw.configure(&[TreeConfig {
        tree: TreeId(2),
        children: 1,
        parent_port: 1,
        op: AggOp::Sum,
    }]);
    assert_eq!(sw.n_trees(), 2);
    let s = sw.stats(TreeId(1)).unwrap();
    assert_eq!(s.pairs_in, 0, "reconfigure must reset engine state");
    let _ = r1;
}

#[test]
fn ingest_sink_capacity_stabilizes_across_streams() {
    // Acceptance check for the zero-alloc ingest path: after one
    // warm-up round the switch's reusable sink must stop growing —
    // i.e. steady-state ingest performs no per-packet allocation.
    let mut rng = Pcg32::new(99);
    let streams: Vec<Vec<KvPair>> = (0..3)
        .map(|_| {
            (0..4_000)
                .map(|_| {
                    KvPair::new(
                        Key::from_id(rng.gen_range_u64(3_000), 16 + (rng.gen_range_u64(49)) as usize),
                        1,
                    )
                })
                .collect()
        })
        .collect();
    let mut sw = configured(16 << 10, Some(256 << 10), 3);
    sw.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
    let warm = sw.sink_capacity();
    assert!(warm > 0, "warm-up round should populate the sink");
    for round in 0..5 {
        let out = sw.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
        assert!(!out.is_empty());
        assert_eq!(
            sw.sink_capacity(),
            warm,
            "sink reallocated on steady-state round {round}"
        );
    }
}

#[test]
fn empty_and_single_pair_streams() {
    let mut sw = configured(16 << 10, Some(1 << 20), 1);
    let out = sw.ingest_stream(TreeId(1), AggOp::Sum, &[]);
    assert!(out.is_empty());

    let mut sw = configured(16 << 10, Some(1 << 20), 1);
    let one = vec![KvPair::new(Key::new(b"solo"), 7)];
    let out = sw.ingest_stream(TreeId(1), AggOp::Sum, &one);
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].value, 7);
}
