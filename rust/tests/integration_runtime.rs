//! Cross-layer integration: the Rust coordinator executing the
//! AOT-compiled JAX/Pallas kernels via PJRT.
//!
//! Requires `make artifacts` (skipped with a message otherwise — but
//! `make test` always builds artifacts first).

use switchagg::protocol::{AggOp, Key, KvPair};
use switchagg::runtime::{AggEngine, XlaAggregator};
use switchagg::switch::hash::fnv1a_words;
use switchagg::util::rng::Pcg32;

fn engine() -> Option<AggEngine> {
    std::env::set_var(
        "SWITCHAGG_ARTIFACTS",
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"),
    );
    match AggEngine::discover() {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping runtime integration: {err:#}");
            None
        }
    }
}

#[test]
fn manifest_matches_engine_constants() {
    let Some(e) = engine() else { return };
    assert_eq!(e.table_size, 65536);
    assert_eq!(e.batch_size, 1024);
    assert_eq!(e.key_words, 16);
    for entry in [
        "agg_sum_f32",
        "agg_max_f32",
        "agg_min_f32",
        "agg_sum_i32",
        "hash_fnv",
        "hash_agg_sum_f32",
    ] {
        assert!(e.has_entry(entry), "missing {entry}");
    }
}

#[test]
fn xla_scatter_sum_matches_rust_reference() {
    let Some(e) = engine() else { return };
    let mut rng = Pcg32::new(1);
    let table = vec![0f32; e.table_size];
    let mut idx = Vec::with_capacity(e.batch_size);
    let mut vals = Vec::with_capacity(e.batch_size);
    let mut want = table.clone();
    for _ in 0..e.batch_size {
        // ~10% padding lanes.
        let slot = if rng.gen_bool(0.1) {
            -1
        } else {
            rng.gen_range_u64(e.table_size as u64) as i32
        };
        let v = (rng.next_f64() * 100.0 - 50.0) as f32;
        if slot >= 0 {
            want[slot as usize] += v;
        }
        idx.push(slot);
        vals.push(v);
    }
    let got = e.aggregate_f32(AggOp::Sum, &table, &idx, &vals).unwrap();
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert!((g - w).abs() < 1e-3, "slot {i}: {g} vs {w}");
    }
}

#[test]
fn xla_max_min_match_rust_reference() {
    let Some(e) = engine() else { return };
    let mut rng = Pcg32::new(2);
    for op in [AggOp::Max, AggOp::Min] {
        let init = match op {
            AggOp::Max => f32::NEG_INFINITY,
            _ => f32::INFINITY,
        };
        let table = vec![init; e.table_size];
        let mut want = table.clone();
        let mut idx = Vec::new();
        let mut vals = Vec::new();
        for _ in 0..e.batch_size {
            let slot = rng.gen_range_u64(256) as i32; // heavy duplicates
            let v = (rng.next_f64() * 10.0) as f32;
            match op {
                AggOp::Max => want[slot as usize] = want[slot as usize].max(v),
                _ => want[slot as usize] = want[slot as usize].min(v),
            }
            idx.push(slot);
            vals.push(v);
        }
        let got = e.aggregate_f32(op, &table, &idx, &vals).unwrap();
        for i in 0..256 {
            assert_eq!(got[i], want[i], "{op} slot {i}");
        }
    }
}

#[test]
fn xla_i32_sum_is_exact() {
    let Some(e) = engine() else { return };
    let table = vec![0i32; e.table_size];
    let mut idx = vec![-1i32; e.batch_size];
    let mut vals = vec![0i32; e.batch_size];
    for i in 0..e.batch_size {
        idx[i] = (i % 100) as i32;
        vals[i] = i as i32;
    }
    let got = e.aggregate_sum_i32(&table, &idx, &vals).unwrap();
    let mut want = vec![0i64; 100];
    for i in 0..e.batch_size {
        want[i % 100] += i as i64;
    }
    for s in 0..100 {
        assert_eq!(got[s] as i64, want[s], "slot {s}");
    }
}

#[test]
fn pallas_hash_is_bit_identical_to_rust_hash() {
    // THE cross-layer contract: rust/src/switch/hash.rs and the Pallas
    // kernel must agree bit-for-bit on every key.
    let Some(e) = engine() else { return };
    let mut rng = Pcg32::new(3);
    let mut words = vec![0u32; e.batch_size * e.key_words];
    for w in words.iter_mut() {
        *w = rng.next_u32();
    }
    let got = e.hash_keys(&words).unwrap();
    for b in 0..e.batch_size {
        let row = &words[b * e.key_words..(b + 1) * e.key_words];
        assert_eq!(got[b], fnv1a_words(row), "row {b}");
    }
}

#[test]
fn pallas_hash_matches_key_packing() {
    // Keys packed by protocol::Key::packed_words hash identically in
    // both languages.
    let Some(e) = engine() else { return };
    let width = e.key_words * 4;
    let mut words = vec![0u32; e.batch_size * e.key_words];
    let mut keys = Vec::new();
    for b in 0..e.batch_size {
        let key = Key::from_id(b as u64, (1 + (b % 64)).max(8));
        let packed = key.packed_words(width);
        words[b * e.key_words..(b + 1) * e.key_words].copy_from_slice(&packed);
        keys.push(key);
    }
    let got = e.hash_keys(&words).unwrap();
    for (b, key) in keys.iter().enumerate() {
        assert_eq!(
            got[b],
            switchagg::switch::hash::fnv1a_key(key, width),
            "key {b}"
        );
    }
}

#[test]
fn xla_aggregator_end_to_end_with_epoch_spill() {
    let Some(e) = engine() else { return };
    let mut agg = XlaAggregator::new(&e, AggOp::Sum);
    let mut rng = Pcg32::new(4);
    let mut want: std::collections::HashMap<Key, i64> = std::collections::HashMap::new();
    for _ in 0..20_000 {
        let id = rng.gen_range_u64(3_000);
        let p = KvPair::new(Key::from_id(id, 16), 2);
        *want.entry(p.key).or_default() += 2;
        agg.offer(p).unwrap();
    }
    let out = agg.drain().unwrap();
    assert_eq!(out.len(), want.len());
    for p in out {
        assert_eq!(p.value, want[&p.key], "key {:?}", p.key);
    }
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some(e) = engine() else { return };
    let err = e.aggregate_f32(AggOp::Sum, &[0.0; 8], &[0; 8], &[0.0; 8]);
    assert!(err.is_err());
    let err = e.hash_keys(&[0u32; 4]);
    assert!(err.is_err());
}
