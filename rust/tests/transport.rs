//! Event-driven transport co-simulation — differential contracts:
//!
//! * **Driver equivalence** — at 0% loss the NetSim-driven session
//!   (`framework::transport`) produces byte-identical final reducer
//!   aggregates to the retained tick-based reference
//!   (`framework::reliable`), scalar and W-lane vector (W ∈ {1, 8})
//!   paths, serial and sharded engines, both credit modes.
//! * **Exactly-once under loss** — lossy/duplicating links change the
//!   timing, never the aggregate.
//! * **RTT estimator** — SRTT/RTTVAR/RTO pinned against an
//!   independent scalar oracle; Karn's rule excludes retransmitted
//!   samples.
//! * **Window unification** — sender credit ceiling and switch dedup
//!   bitmap derive from one `RelWindow`, so mismatched ends are
//!   unrepresentable.

use std::collections::HashMap;
use switchagg::framework::reliable::{
    run_reliable_scalar, run_reliable_vector, ReliabilityConfig,
};
use switchagg::framework::transport::{
    run_transport_scalar, run_transport_vector, CreditMode, TransportConfig,
};
use switchagg::framework::Reducer;
use switchagg::protocol::{
    AggOp, Key, KvPair, RelWindow, ReliableSender, RttEstimator, TreeConfig, TreeId, Value,
    VectorBatch,
};
use switchagg::switch::{DedupWindow, Parallelism, SwitchAggSwitch, SwitchConfig};
use switchagg::util::miniprop::prop;
use switchagg::util::rng::Pcg32;

fn scalar_switch(children: u16, par: Parallelism) -> SwitchAggSwitch {
    let cfg = SwitchConfig {
        parallelism: par,
        ..SwitchConfig::scaled(16 << 10, Some(256 << 10))
    };
    let mut sw = SwitchAggSwitch::new(cfg);
    sw.configure(&[TreeConfig {
        tree: TreeId(1),
        children,
        parent_port: 0,
        op: AggOp::Sum,
    }]);
    sw
}

fn vector_switch(children: u16, lanes: usize) -> SwitchAggSwitch {
    let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(32 << 10, Some(512 << 10)));
    sw.configure_vector(
        &[TreeConfig {
            tree: TreeId(1),
            children,
            parent_port: 0,
            op: AggOp::Sum,
        }],
        lanes,
    );
    sw
}

fn scalar_streams(children: usize, n: usize, seed: u64) -> Vec<Vec<KvPair>> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0x77);
            (0..n)
                .map(|_| {
                    let id = child.gen_range_u64(400);
                    KvPair::new(
                        Key::from_id(id, 16 + (id % 49) as usize),
                        child.gen_range_u64(200) as i64 - 100,
                    )
                })
                .collect()
        })
        .collect()
}

fn vector_streams(children: usize, n: usize, lanes: usize, seed: u64) -> Vec<VectorBatch> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0x88);
            let mut b = VectorBatch::new(lanes);
            let mut vals: Vec<Value> = vec![0; lanes];
            for _ in 0..n {
                let id = child.gen_range_u64(300);
                for (l, v) in vals.iter_mut().enumerate() {
                    *v = (id % 11) as i64 + l as i64 - 5;
                }
                b.push(Key::from_id(id, 16 + (id % 49) as usize), &vals);
            }
            b
        })
        .collect()
}

fn merged(pairs: &[KvPair]) -> HashMap<Key, Value> {
    Reducer::merge_software(&[pairs.to_vec()], AggOp::Sum).table
}

/// Lane-wise key → vector-sum map (order-free vector aggregate).
fn merged_lanes(batch: &VectorBatch) -> HashMap<Key, Vec<Value>> {
    let mut out: HashMap<Key, Vec<Value>> = HashMap::new();
    for (k, lanes) in batch.iter() {
        let e = out.entry(*k).or_insert_with(|| vec![0; lanes.len()]);
        for (acc, v) in e.iter_mut().zip(lanes) {
            *acc += v;
        }
    }
    out
}

#[test]
fn event_driver_matches_tick_reference_scalar_lossless() {
    let ss = scalar_streams(3, 1_500, 5);
    let mut tick_sw = scalar_switch(3, Parallelism::Serial);
    let tick = run_reliable_scalar(
        &mut tick_sw,
        TreeId(1),
        AggOp::Sum,
        &ss,
        &ReliabilityConfig::default(),
    );
    let want = merged(&tick.received);
    for par in [Parallelism::Serial, Parallelism::Sharded(4)] {
        for mode in [CreditMode::Adaptive, CreditMode::FixedWindow] {
            let mut sw = scalar_switch(3, par);
            let run = run_transport_scalar(
                &mut sw,
                TreeId(1),
                AggOp::Sum,
                &ss,
                &TransportConfig::default().with_mode(mode),
            );
            assert_eq!(run.ingress.retransmissions, 0, "{par:?}/{mode:?}");
            assert!(run.completeness.is_complete());
            assert_eq!(
                merged(&run.received),
                want,
                "event-driven aggregate diverged from the tick reference ({par:?}/{mode:?})"
            );
        }
    }
}

#[test]
fn event_driver_matches_tick_reference_vector_lossless() {
    for lanes in [1usize, 8] {
        let ss = vector_streams(2, 1_000, lanes, 9);
        let mut tick_sw = vector_switch(2, lanes);
        let tick = run_reliable_vector(
            &mut tick_sw,
            TreeId(1),
            AggOp::Sum,
            &ss,
            &ReliabilityConfig::default(),
        );
        let want = merged_lanes(&tick.received);
        let mut sw = vector_switch(2, lanes);
        let run = run_transport_vector(
            &mut sw,
            TreeId(1),
            AggOp::Sum,
            &ss,
            &TransportConfig::default(),
        );
        assert_eq!(run.ingress.retransmissions, 0);
        assert!(run.completeness.is_complete());
        assert_eq!(
            merged_lanes(&run.received),
            want,
            "W={lanes} vector aggregate diverged from the tick reference"
        );
    }
}

#[test]
fn scalar_w1_vector_transport_agree() {
    // The degenerate 1-lane vector session and the scalar session on
    // the same logical stream land on the same aggregate.
    let ss = scalar_streams(2, 800, 21);
    let mut sw = scalar_switch(2, Parallelism::Serial);
    let scalar = run_transport_scalar(
        &mut sw,
        TreeId(1),
        AggOp::Sum,
        &ss,
        &TransportConfig::default(),
    );
    let vb: Vec<VectorBatch> = ss.iter().map(|s| VectorBatch::from_pairs(s)).collect();
    let mut vsw = vector_switch(2, 1);
    let vector = run_transport_vector(
        &mut vsw,
        TreeId(1),
        AggOp::Sum,
        &vb,
        &TransportConfig::default(),
    );
    let scalar_as_lanes: HashMap<Key, Vec<Value>> = merged(&scalar.received)
        .into_iter()
        .map(|(k, v)| (k, vec![v]))
        .collect();
    assert_eq!(merged_lanes(&vector.received), scalar_as_lanes);
}

#[test]
fn lossy_transport_is_exact_across_modes_and_engines() {
    let ss = scalar_streams(4, 1_200, 33);
    let mut base_sw = scalar_switch(4, Parallelism::Serial);
    let base = run_reliable_scalar(
        &mut base_sw,
        TreeId(1),
        AggOp::Sum,
        &ss,
        &ReliabilityConfig::default(),
    );
    let want = merged(&base.received);
    for par in [Parallelism::Serial, Parallelism::Sharded(2)] {
        for mode in [CreditMode::Adaptive, CreditMode::FixedWindow] {
            let mut sw = scalar_switch(4, par);
            let cfg = TransportConfig::uniform(0.05, 0xBAD).with_dup(0.03).with_mode(mode);
            let run = run_transport_scalar(&mut sw, TreeId(1), AggOp::Sum, &ss, &cfg);
            assert!(run.ingress.drops > 0, "{par:?}/{mode:?}");
            assert!(run.completeness.is_complete());
            assert_eq!(merged(&run.received), want, "{par:?}/{mode:?}");
        }
    }
}

#[test]
fn lossy_vector_transport_is_exact() {
    let lanes = 8;
    let ss = vector_streams(3, 600, lanes, 41);
    let mut base_sw = vector_switch(3, lanes);
    let base = run_transport_vector(
        &mut base_sw,
        TreeId(1),
        AggOp::Sum,
        &ss,
        &TransportConfig::default(),
    );
    let mut sw = vector_switch(3, lanes);
    let run = run_transport_vector(
        &mut sw,
        TreeId(1),
        AggOp::Sum,
        &ss,
        &TransportConfig::uniform(0.08, 0xF00),
    );
    assert!(run.ingress.retransmissions > 0);
    assert_eq!(merged_lanes(&run.received), merged_lanes(&base.received));
}

#[test]
fn transport_is_deterministic() {
    let go = || {
        let ss = scalar_streams(2, 700, 13);
        let mut sw = scalar_switch(2, Parallelism::Serial);
        let run = run_transport_scalar(
            &mut sw,
            TreeId(1),
            AggOp::Sum,
            &ss,
            &TransportConfig::uniform(0.05, 0xD5),
        );
        (
            run.jct_s,
            run.ingress.retransmissions,
            run.ingress.drops,
            run.received,
        )
    };
    let a = go();
    let b = go();
    assert_eq!(a.0, b.0, "same seed ⇒ bit-identical JCT");
    assert_eq!((a.1, a.2), (b.1, b.2));
    assert_eq!(a.3, b.3);
}

// --- RTT estimator: scalar oracle + Karn exclusion ------------------

#[test]
fn prop_rtt_estimator_matches_scalar_oracle() {
    prop("rtt estimator vs RFC 6298 oracle", 128, |rng| {
        let init = 1e-4 + rng.gen_range_u64(1_000) as f64 * 1e-6;
        let min_rto = init / (2 + rng.gen_range_u64(8)) as f64;
        let mut est = RttEstimator::new(init, min_rto);
        // Independent oracle state (straight from the RFC text).
        let mut srtt: Option<f64> = None;
        let mut rttvar = 0.0f64;
        let max_rto = init * 64.0;
        for _ in 0..rng.gen_range_u64(40) + 1 {
            if rng.gen_bool(0.2) {
                // Timeout: both sides double (capped).
                let expect = (est.rto_s() * 2.0).min(max_rto);
                est.on_timeout();
                if (est.rto_s() - expect).abs() > 1e-15 {
                    return Err(format!("backoff: {} vs {}", est.rto_s(), expect));
                }
                continue;
            }
            let r = rng.gen_range_u64(500_000) as f64 * 1e-9; // 0..500µs
            est.on_sample(r);
            match srtt {
                None => {
                    srtt = Some(r);
                    rttvar = r / 2.0;
                }
                Some(s) => {
                    rttvar = 0.75 * rttvar + 0.25 * (s - r).abs();
                    srtt = Some(0.875 * s + 0.125 * r);
                }
            }
            let want_rto = (srtt.unwrap() + 4.0 * rttvar).clamp(min_rto, max_rto);
            let got_srtt = est.srtt_s().unwrap();
            if (got_srtt - srtt.unwrap()).abs() > 1e-15
                || (est.rttvar_s() - rttvar).abs() > 1e-15
                || (est.rto_s() - want_rto).abs() > 1e-15
            {
                return Err(format!(
                    "srtt {} vs {}, rttvar {} vs {}, rto {} vs {}",
                    got_srtt,
                    srtt.unwrap(),
                    est.rttvar_s(),
                    rttvar,
                    est.rto_s(),
                    want_rto
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_karn_rule_never_samples_retransmitted_packets() {
    use switchagg::protocol::AdaptiveSender;
    prop("karn exclusion under random ack/timeout schedules", 64, |rng| {
        let total = (rng.gen_range_u64(40) + 5) as usize;
        let mut s = AdaptiveSender::adaptive(
            total,
            RelWindow::default(),
            RttEstimator::new(100e-6, 1e-6),
        );
        let mut out = Vec::new();
        let mut now = 0.0f64;
        let mut retransmitted_any = false;
        for _ in 0..200 {
            if s.done() {
                break;
            }
            out.clear();
            s.poll(now, &mut out);
            let before = s.retransmissions;
            // Randomly ack some prefix (sometimes stale), or let time
            // pass beyond the RTO so everything in flight retransmits.
            if rng.gen_bool(0.5) {
                let cum = rng.gen_range_u64(total as u64 + 1) as u32;
                s.on_ack(cum, u16::MAX, now);
            } else {
                now += s.rtt().rto_s() + 1e-6;
                out.clear();
                s.poll(now, &mut out);
                if s.retransmissions > before {
                    retransmitted_any = true;
                }
            }
            now += 1e-6;
        }
        // The estimator may hold samples — but only from packets acked
        // before their first retransmission.  The stress here is that
        // nothing panics and srtt stays finite & sane.
        if let Some(srtt) = s.rtt().srtt_s() {
            if !(srtt.is_finite() && srtt >= 0.0) {
                return Err(format!("bad srtt {srtt}"));
            }
            if srtt > 1.0 {
                return Err(format!(
                    "srtt {srtt} can only get that large by sampling a \
                     retransmitted packet (retransmitted_any={retransmitted_any})"
                ));
            }
        }
        Ok(())
    });
}

// --- Window unification ---------------------------------------------

#[test]
fn one_rel_window_constructs_both_ends() {
    let shared = RelWindow::new(32);
    let sender = ReliableSender::with_window(10_000, 2, shared);
    let dedup = DedupWindow::sized(shared);
    assert_eq!(sender.credit(), dedup.credit() as u32);
    assert_eq!(sender.credit(), 32);
}

#[test]
fn transport_respects_a_tiny_shared_window() {
    let ss = scalar_streams(2, 500, 3);
    let mut sw = scalar_switch(2, Parallelism::Serial);
    let cfg = TransportConfig::uniform(0.03, 0x3333).with_window(RelWindow::new(4));
    let run = run_transport_scalar(&mut sw, TreeId(1), AggOp::Sum, &ss, &cfg);
    assert!(run.completeness.is_complete());
    assert_eq!(
        sw.dedup_stats(TreeId(1)).out_of_window,
        0,
        "shared-window senders can never overrun the switch bitmap"
    );
    assert!(run.ingress.cwnd_peak <= 4.0, "cwnd capped by the window");
}

// --- Satellite guards ------------------------------------------------

#[test]
fn fifo_peak_occupancy_is_exposed_and_plausible() {
    // A 64-child incast at line rate must queue at the PE inputs; the
    // peak must be visible through SwitchStats and bounded by the cap.
    let ss = scalar_streams(64, 200, 17);
    let mut sw = scalar_switch(64, Parallelism::Serial);
    let run = run_transport_scalar(
        &mut sw,
        TreeId(1),
        AggOp::Sum,
        &ss,
        &TransportConfig::default(),
    );
    let stats = sw.stats(TreeId(1)).unwrap();
    assert_eq!(run.fifo_peak, stats.fifo_max_occupancy);
    assert!(stats.fifo_max_occupancy > 0, "ingest must touch the FIFOs");
    let single = {
        let ss1 = scalar_streams(1, 200, 17);
        let mut sw1 = scalar_switch(1, Parallelism::Serial);
        run_transport_scalar(
            &mut sw1,
            TreeId(1),
            AggOp::Sum,
            &ss1,
            &TransportConfig::default(),
        )
        .fifo_peak
    };
    assert!(
        stats.fifo_max_occupancy >= single,
        "64-to-1 incast cannot queue less than a single stream \
         ({} vs {single})",
        stats.fifo_max_occupancy
    );
}
