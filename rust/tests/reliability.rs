//! Exactly-once aggregation under packet loss — the reliability
//! subsystem's differential contracts:
//!
//! * **Loss-rate invariance** — for one workload and seed, the final
//!   reducer output (keys, values, counts) is byte-identical at 0%,
//!   1%, and 10% link loss, on the serial and sharded engines, scalar
//!   and W-lane vector paths alike.
//! * **Legacy equivalence** — with loss disabled, the reliable path
//!   produces the same final aggregate as the existing (unreliable)
//!   ingest entry points.
//! * **Duplication robustness** — a duplicating channel changes
//!   nothing: the switch dedup window drops every copy but the first.

use std::collections::{BTreeMap, HashMap};
use switchagg::framework::reliable::{
    run_reliable_scalar, run_reliable_vector, ReliabilityConfig,
};
use switchagg::framework::Reducer;
use switchagg::protocol::{AggOp, Key, KvPair, TreeConfig, TreeId, Value, VectorBatch};
use switchagg::switch::{Parallelism, SwitchAggSwitch, SwitchConfig};
use switchagg::util::miniprop::prop;
use switchagg::util::rng::Pcg32;

fn random_pairs(rng: &mut Pcg32, n: usize, variety: u64) -> Vec<KvPair> {
    (0..n)
        .map(|_| {
            let id = rng.gen_range_u64(variety);
            let len = 8 + (rng.gen_range_u64(57) as usize);
            KvPair::new(Key::from_id(id, len), rng.gen_range_u64(1000) as i64 - 500)
        })
        .collect()
}

fn scalar_switch(children: u16, par: Parallelism) -> SwitchAggSwitch {
    let cfg = SwitchConfig {
        parallelism: par,
        ..SwitchConfig::scaled(16 << 10, Some(256 << 10))
    };
    let mut sw = SwitchAggSwitch::new(cfg);
    sw.configure(&[TreeConfig {
        tree: TreeId(1),
        children,
        parent_port: 0,
        op: AggOp::Sum,
    }]);
    sw
}

fn vector_switch(children: u16, lanes: usize) -> SwitchAggSwitch {
    let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(32 << 10, Some(512 << 10)));
    sw.configure_vector(
        &[TreeConfig {
            tree: TreeId(1),
            children,
            parent_port: 0,
            op: AggOp::Sum,
        }],
        lanes,
    );
    sw
}

/// The reducer's final output in canonical (sorted) form: key →
/// combined value.  The key set, every value, and the key *count* are
/// all pinned by equality on this map — what arrival order may change
/// is only how the switch *partitions* a key's total into partial
/// pairs, never the reduced result.
fn final_aggregate(pairs: &[KvPair]) -> BTreeMap<Vec<u8>, Value> {
    let mut out: BTreeMap<Vec<u8>, Value> = BTreeMap::new();
    for p in pairs {
        *out.entry(p.key.as_bytes().to_vec()).or_insert(0) += p.value;
    }
    out
}

fn vector_aggregate(batch: &VectorBatch) -> BTreeMap<Vec<u8>, Vec<Value>> {
    let lanes = batch.lanes();
    let mut out: BTreeMap<Vec<u8>, Vec<Value>> = BTreeMap::new();
    for (k, ls) in batch.iter() {
        let e = out
            .entry(k.as_bytes().to_vec())
            .or_insert_with(|| vec![0; lanes]);
        for (a, v) in e.iter_mut().zip(ls) {
            *a += v;
        }
    }
    out
}

#[test]
fn scalar_output_is_identical_at_0_1_and_10_percent_loss() {
    let mut rng = Pcg32::new(0x10DD);
    let streams: Vec<Vec<KvPair>> = (0..3).map(|_| random_pairs(&mut rng, 2_500, 400)).collect();
    for par in [Parallelism::Serial, Parallelism::Sharded(4)] {
        let mut base_sw = scalar_switch(3, par);
        let base = run_reliable_scalar(
            &mut base_sw,
            TreeId(1),
            AggOp::Sum,
            &streams,
            &ReliabilityConfig::default(),
        );
        let base_agg = final_aggregate(&base.received);
        // Conservation: the lossless aggregate holds exactly the
        // input's per-key totals.
        let input: Vec<KvPair> = streams.iter().flatten().copied().collect();
        assert_eq!(base_agg, final_aggregate(&input));
        for loss in [0.01, 0.10] {
            let mut sw = scalar_switch(3, par);
            let run = run_reliable_scalar(
                &mut sw,
                TreeId(1),
                AggOp::Sum,
                &streams,
                &ReliabilityConfig::uniform(loss, 0xFEED),
            );
            assert!(run.completeness.is_complete());
            assert_eq!(
                final_aggregate(&run.received),
                base_agg,
                "aggregate diverged at {loss} loss ({par:?})"
            );
            if loss >= 0.10 {
                assert!(run.ingress.retransmissions > 0, "{par:?}");
            }
        }
    }
}

#[test]
fn vector_output_is_identical_at_0_1_and_10_percent_loss() {
    for lanes in [1usize, 8] {
        let mut rng = Pcg32::new(0x7EC + lanes as u64);
        let streams: Vec<VectorBatch> = (0..2)
            .map(|_| {
                let mut b = VectorBatch::new(lanes);
                let mut vals = vec![0i64; lanes];
                for _ in 0..1_500 {
                    let id = rng.gen_range_u64(300);
                    for (l, v) in vals.iter_mut().enumerate() {
                        *v = (id % 13) as i64 + l as i64 - 6;
                    }
                    b.push(Key::from_id(id, 16 + (id % 49) as usize), &vals);
                }
                b
            })
            .collect();
        let mut base_sw = vector_switch(2, lanes);
        let base = run_reliable_vector(
            &mut base_sw,
            TreeId(1),
            AggOp::Sum,
            &streams,
            &ReliabilityConfig::default(),
        );
        let base_agg = vector_aggregate(&base.received);
        for loss in [0.01, 0.10] {
            let mut sw = vector_switch(2, lanes);
            let run = run_reliable_vector(
                &mut sw,
                TreeId(1),
                AggOp::Sum,
                &streams,
                &ReliabilityConfig::uniform(loss, 0xBEE),
            );
            assert!(run.completeness.is_complete());
            assert_eq!(
                vector_aggregate(&run.received),
                base_agg,
                "vector aggregate diverged at {loss} loss (W={lanes})"
            );
        }
    }
}

#[test]
fn lossless_reliable_path_matches_legacy_unreliable_ingest() {
    let mut rng = Pcg32::new(0x1E6);
    let streams: Vec<Vec<KvPair>> = (0..3).map(|_| random_pairs(&mut rng, 2_000, 350)).collect();
    let mut legacy_sw = scalar_switch(3, Parallelism::Serial);
    let legacy_out = legacy_sw.ingest_child_streams(TreeId(1), AggOp::Sum, &streams);
    let mut sw = scalar_switch(3, Parallelism::Serial);
    let run = run_reliable_scalar(
        &mut sw,
        TreeId(1),
        AggOp::Sum,
        &streams,
        &ReliabilityConfig::default(),
    );
    assert_eq!(final_aggregate(&run.received), final_aggregate(&legacy_out));
    // Software-reducer maps agree too (the user-visible result).
    let a: HashMap<Key, Value> =
        Reducer::merge_software(&[run.received.clone()], AggOp::Sum).table;
    let b: HashMap<Key, Value> = Reducer::merge_software(&[legacy_out], AggOp::Sum).table;
    assert_eq!(a, b);
}

#[test]
fn prop_reliable_sessions_are_exactly_once() {
    // Random children, stream sizes, loss/dup rates, engines: the
    // final aggregate must always equal the lossless aggregate of the
    // same workload, and completeness must always certify.
    prop("reliable session == lossless aggregate", 8, |rng| {
        let children = 1 + rng.gen_range_usize(3) as u16;
        let variety = 1 << (5 + rng.gen_range_usize(5));
        let streams: Vec<Vec<KvPair>> = (0..children as usize)
            .map(|_| {
                let n = 300 + rng.gen_range_usize(1_500);
                random_pairs(rng, n, variety)
            })
            .collect();
        let par = if rng.gen_bool(0.5) {
            Parallelism::Serial
        } else {
            Parallelism::Sharded(1 + rng.gen_range_usize(4))
        };
        let mut base_sw = scalar_switch(children, par);
        let base = run_reliable_scalar(
            &mut base_sw,
            TreeId(1),
            AggOp::Sum,
            &streams,
            &ReliabilityConfig::default(),
        );
        let want = final_aggregate(&base.received);
        let input: Vec<KvPair> = streams.iter().flatten().copied().collect();
        if want != final_aggregate(&input) {
            return Err("lossless run does not conserve the input aggregate".into());
        }

        let loss = 0.02 + rng.next_f64() * 0.13; // 2%..15%
        let dup = if rng.gen_bool(0.5) { 0.05 } else { 0.0 };
        let cfg = ReliabilityConfig::uniform(loss, rng.next_u64()).with_dup(dup);
        let mut sw = scalar_switch(children, par);
        let run = run_reliable_scalar(&mut sw, TreeId(1), AggOp::Sum, &streams, &cfg);
        if !run.completeness.is_complete() {
            return Err(format!("incomplete at loss={loss:.3}"));
        }
        if final_aggregate(&run.received) != want {
            return Err(format!(
                "aggregate diverged at loss={loss:.3} dup={dup} children={children} {par:?}"
            ));
        }
        Ok(())
    });
}
