//! End-to-end integrity — differential contracts:
//!
//! * **Zero-cost trailer** — with zero corruption, the CRC-enabled
//!   integrity driver is byte-identical (received stream *and* JCT) to
//!   the legacy event-driven transport, scalar and W-lane vector
//!   (W ∈ {1, 8}) paths, serial and sharded engines.  The CRC32C
//!   trailer repurposes the modeled Ethernet FCS, so protection
//!   changes nothing until a bit actually flips.
//! * **Corrupt-ack recovery** — a flipped ack is detected and
//!   discarded; the data path recovers it like a lost ack, and the
//!   aggregate is exact.
//! * **Corrupt-EoT recovery** — an EoT whose flag bit was flipped away
//!   can never fire the flush; the session-end forced flush drains the
//!   residents and the aggregate is exact.
//! * **Decode robustness** — a structure-aware fuzz over every packet
//!   tag: truncation, bit flips, and length inflation must never
//!   panic the decoder or make it over-commit memory.

use std::collections::HashMap;
use switchagg::framework::integrity::{
    run_integrity_scalar, run_integrity_vector, IntegrityConfig,
};
use switchagg::framework::transport::{
    run_transport_scalar, run_transport_vector, TransportConfig,
};
use switchagg::framework::Reducer;
use switchagg::net::LossConfig;
use switchagg::protocol::{
    AckKind, AggAckPacket, AggOp, AggregationPacket, ConfigurePacket, DataPacket, Key, KvPair,
    LaunchPacket, Packet, RelHeader, TreeConfig, TreeId, Value, VectorAggregationPacket,
    VectorBatch,
};
use switchagg::switch::{IngestSink, Parallelism, SwitchAggSwitch, SwitchConfig};
use switchagg::util::miniprop::prop;
use switchagg::util::rng::Pcg32;

fn scalar_switch(children: u16, par: Parallelism) -> SwitchAggSwitch {
    let cfg = SwitchConfig {
        parallelism: par,
        ..SwitchConfig::scaled(16 << 10, Some(256 << 10))
    };
    let mut sw = SwitchAggSwitch::new(cfg);
    sw.configure(&[TreeConfig {
        tree: TreeId(1),
        children,
        parent_port: 0,
        op: AggOp::Sum,
    }]);
    sw
}

fn vector_switch(children: u16, lanes: usize) -> SwitchAggSwitch {
    let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(32 << 10, Some(512 << 10)));
    sw.configure_vector(
        &[TreeConfig {
            tree: TreeId(1),
            children,
            parent_port: 0,
            op: AggOp::Sum,
        }],
        lanes,
    );
    sw
}

fn scalar_streams(children: usize, n: usize, seed: u64) -> Vec<Vec<KvPair>> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0x1D);
            (0..n)
                .map(|_| {
                    let id = child.gen_range_u64(400);
                    KvPair::new(
                        Key::from_id(id, 16 + (id % 49) as usize),
                        child.gen_range_u64(200) as i64 - 100,
                    )
                })
                .collect()
        })
        .collect()
}

fn vector_streams(children: usize, n: usize, lanes: usize, seed: u64) -> Vec<VectorBatch> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0x2E);
            let mut b = VectorBatch::new(lanes);
            let mut vals: Vec<Value> = vec![0; lanes];
            for _ in 0..n {
                let id = child.gen_range_u64(300);
                for (l, v) in vals.iter_mut().enumerate() {
                    *v = (id % 11) as i64 + l as i64 - 5;
                }
                b.push(Key::from_id(id, 16 + (id % 49) as usize), &vals);
            }
            b
        })
        .collect()
}

fn merged(pairs: &[KvPair]) -> HashMap<Key, Value> {
    Reducer::merge_software(&[pairs.to_vec()], AggOp::Sum).table
}

#[test]
fn crc_on_zero_corruption_is_byte_identical_to_legacy_scalar() {
    let ss = scalar_streams(3, 1_200, 5);
    for par in [Parallelism::Serial, Parallelism::Sharded(4)] {
        let mut legacy_sw = scalar_switch(3, par);
        let legacy = run_transport_scalar(
            &mut legacy_sw,
            TreeId(1),
            AggOp::Sum,
            &ss,
            &TransportConfig::default(),
        );
        let mut sw = scalar_switch(3, par);
        let run = run_integrity_scalar(
            &mut sw,
            TreeId(1),
            AggOp::Sum,
            &ss,
            &IntegrityConfig::default(),
        );
        assert_eq!(
            run.received, legacy.received,
            "CRC-on zero-corruption stream diverged ({par:?})"
        );
        assert_eq!(run.jct_s, legacy.jct_s, "wire schedule diverged ({par:?})");
        assert_eq!(run.ingress.corrupted, 0);
        assert_eq!(run.ingress.first_tx, legacy.ingress.first_tx);
        assert_eq!(run.ingress.wire_bytes, legacy.ingress.wire_bytes);
        assert!(run.exact, "{par:?}");
        assert!(run.reducer_audit.is_ok(), "{par:?}");
    }
}

#[test]
fn crc_on_zero_corruption_is_byte_identical_to_legacy_vector() {
    for lanes in [1usize, 8] {
        let ss = vector_streams(2, 900, lanes, 9);
        let mut legacy_sw = vector_switch(2, lanes);
        let legacy = run_transport_vector(
            &mut legacy_sw,
            TreeId(1),
            AggOp::Sum,
            &ss,
            &TransportConfig::default(),
        );
        let mut sw = vector_switch(2, lanes);
        let run = run_integrity_vector(
            &mut sw,
            TreeId(1),
            AggOp::Sum,
            &ss,
            &IntegrityConfig::default(),
        );
        assert_eq!(
            run.received, legacy.received,
            "W={lanes} CRC-on zero-corruption batch diverged"
        );
        assert_eq!(run.jct_s, legacy.jct_s, "W={lanes} wire schedule diverged");
        assert!(run.exact, "W={lanes}");
    }
}

#[test]
fn corrupt_data_session_recovers_exactly_serial_and_sharded() {
    let ss = scalar_streams(2, 1_500, 13);
    let want = merged(&ss.concat());
    for par in [Parallelism::Serial, Parallelism::Sharded(4)] {
        let mut sw = scalar_switch(2, par);
        let run = run_integrity_scalar(
            &mut sw,
            TreeId(1),
            AggOp::Sum,
            &ss,
            &IntegrityConfig::corrupting(0.2, 0xD1CE),
        );
        assert!(run.ingress.corrupted > 0, "{par:?}");
        assert!(run.ingress.corrupt_drops > 0, "{par:?}");
        assert_eq!(run.silently_admitted, 0, "{par:?}: a flip survived the CRC");
        assert_eq!(merged(&run.received), want, "{par:?}");
        assert!(run.exact, "{par:?}");
    }
}

#[test]
fn corrupt_ack_session_recovers_exactly() {
    let ss = scalar_streams(2, 1_200, 17);
    let mut cfg = IntegrityConfig::default();
    cfg.transport.ack = LossConfig::corrupt(0.3, 0xACE5);
    let mut sw = scalar_switch(2, Parallelism::Serial);
    let run = run_integrity_scalar(&mut sw, TreeId(1), AggOp::Sum, &ss, &cfg);
    assert!(
        run.ingress.acks_corrupt_dropped > 0,
        "30% ack corruption must discard some acks"
    );
    assert_eq!(run.ingress.drops, 0, "only acks were corrupted");
    assert_eq!(merged(&run.received), merged(&ss.concat()));
    assert!(run.exact);
    assert!(run.reducer_audit.is_ok());
}

#[test]
fn corrupt_eot_is_recovered_by_forced_flush() {
    // An admitted data packet whose EoT flag bit was flipped away (the
    // legacy-format failure `framework::integrity` counts as
    // `forced_flushes`): the eot quorum can never fire the flush, so
    // the session-end fallback must drain the residents — and the
    // drained aggregate must still be exact.
    let ss = scalar_streams(2, 800, 23);
    let mut sw = scalar_switch(2, Parallelism::Serial);
    let mut sink = IngestSink::new();
    for (c, s) in ss.iter().enumerate() {
        // eot = false on every packet simulates the flipped-away flag.
        let mut pkts = AggregationPacket::pack_stream(TreeId(1), AggOp::Sum, s, false);
        let mut seq = 0u32;
        for p in &mut pkts {
            seq += 1;
            p.rel = Some(RelHeader {
                child: c as u16,
                epoch: 0,
                seq,
            });
        }
        for p in &pkts {
            sw.ingest_reliable_one(TreeId(1), p, &mut sink);
        }
    }
    assert_eq!(sink.flushes, 0, "no EoT ⇒ the quorum flush never fires");
    assert!(sw.force_flush(TreeId(1), &mut sink));
    assert_eq!(sink.flushes, 1);
    let mut out = sink.forwarded.clone();
    out.extend_from_slice(&sink.flushed);
    assert_eq!(merged(&out), merged(&ss.concat()), "forced flush lost pairs");
}

/// Build one random valid packet of every wire tag.
fn random_packet(rng: &mut Pcg32) -> Packet {
    let pairs = |rng: &mut Pcg32, n: usize| -> Vec<KvPair> {
        (0..n)
            .map(|_| {
                let id = rng.gen_range_u64(1 << 16);
                KvPair::new(
                    Key::from_id(id, 8 + rng.gen_range_usize(57)),
                    rng.gen_range_u64(1000) as i64 - 500,
                )
            })
            .collect()
    };
    let rel = |rng: &mut Pcg32| -> Option<RelHeader> {
        rng.gen_bool(0.5).then(|| RelHeader {
            child: rng.gen_range_u64(64) as u16,
            epoch: rng.gen_range_u64(8) as u16,
            seq: rng.next_u32(),
        })
    };
    match rng.gen_range_usize(7) {
        0 => Packet::Launch(LaunchPacket {
            mappers: (0..rng.gen_range_usize(8)).map(|i| i as u32).collect(),
            reducers: (0..rng.gen_range_usize(4)).map(|i| i as u32).collect(),
        }),
        1 => Packet::Configure(ConfigurePacket {
            trees: (0..rng.gen_range_usize(4))
                .map(|i| TreeConfig {
                    tree: TreeId(i as u32),
                    children: 1 + rng.gen_range_u64(16) as u16,
                    parent_port: rng.gen_range_u64(64) as u8,
                    op: AggOp::ALL[rng.gen_range_usize(3)],
                })
                .collect(),
        }),
        2 => Packet::Ack(if rng.gen_bool(0.5) {
            AckKind::Master
        } else {
            AckKind::Switch
        }),
        3 => Packet::Aggregation(AggregationPacket {
            tree: TreeId(rng.next_u32()),
            op: AggOp::ALL[rng.gen_range_usize(3)],
            eot: rng.gen_bool(0.5),
            rel: rel(rng),
            pairs: pairs(rng, rng.gen_range_usize(30)),
        }),
        4 => {
            let lanes = 1 + rng.gen_range_usize(8);
            let mut batch = VectorBatch::new(lanes);
            let vals: Vec<Value> = (0..lanes).map(|l| l as i64 - 3).collect();
            for _ in 0..rng.gen_range_usize(20) {
                batch.push(Key::from_id(rng.gen_range_u64(1 << 12), 16), &vals);
            }
            Packet::VectorAggregation(VectorAggregationPacket {
                tree: TreeId(rng.next_u32()),
                op: AggOp::ALL[rng.gen_range_usize(3)],
                eot: rng.gen_bool(0.5),
                rel: rel(rng),
                batch,
            })
        }
        5 => Packet::Data(DataPacket {
            payload_len: rng.next_u32() >> 12,
        }),
        _ => Packet::AggAck(AggAckPacket {
            tree: TreeId(rng.next_u32()),
            child: rng.gen_range_u64(64) as u16,
            epoch: rng.gen_range_u64(8) as u16,
            cum_seq: rng.next_u32(),
            credit: rng.gen_range_u64(1024) as u16,
        }),
    }
}

/// Decode must be total: whatever the damage, it returns a typed error
/// or a structurally sane packet — never a panic, never an allocation
/// driven by an attacker-controlled length field.
fn check_decode_total(buf: &[u8]) -> Result<(), String> {
    match Packet::decode(buf) {
        Err(_) => Ok(()),
        Ok(Packet::Aggregation(p)) => {
            // A pair is ≥ 7 encoded bytes (MIN_PAIR), so a sane decode
            // can never hold more pairs than the buffer could encode.
            if p.pairs.len() > buf.len() {
                return Err(format!(
                    "{} pairs decoded out of a {}-byte buffer",
                    p.pairs.len(),
                    buf.len()
                ));
            }
            Ok(())
        }
        Ok(Packet::VectorAggregation(p)) => {
            if p.batch.len() > buf.len() {
                return Err(format!(
                    "{} rows decoded out of a {}-byte buffer",
                    p.batch.len(),
                    buf.len()
                ));
            }
            Ok(())
        }
        Ok(_) => Ok(()),
    }
}

#[test]
fn prop_decode_survives_corruption_of_every_tag() {
    prop("decode is total under corruption", 400, |rng| {
        let pkt = random_packet(rng);
        let clean = if rng.gen_bool(0.5) {
            pkt.encode_integrity()
        } else {
            pkt.encode()
        };
        // Truncation at every prefix of a small packet, random prefix
        // of a large one.
        let cut = rng.gen_range_usize(clean.len() + 1);
        check_decode_total(&clean[..cut])?;
        // 1–8 random bit flips.
        let mut flipped = clean.clone();
        for _ in 0..1 + rng.gen_range_usize(8) {
            let bit = rng.gen_range_usize(flipped.len() * 8);
            flipped[bit / 8] ^= 1 << (bit % 8);
        }
        check_decode_total(&flipped)?;
        // Length inflation: junk appended to a valid frame (and to a
        // flipped one) must not decode into phantom content.
        let mut inflated = clean.clone();
        for _ in 0..1 + rng.gen_range_usize(64) {
            inflated.push(rng.next_u32() as u8);
        }
        check_decode_total(&inflated)?;
        flipped.extend_from_slice(&inflated[clean.len()..]);
        check_decode_total(&flipped)?;
        Ok(())
    });
}

/// A relay-role frame (`framework::pipeline` switch→switch hop): an
/// aggregation packet that *always* carries a [`RelHeader`] — the rack
/// index rides in `child`, the stream position in `seq`, and the last
/// frame sets `eot` to arm the spine's flush quorum.
fn random_relay_packet(rng: &mut Pcg32, eot: bool) -> Packet {
    let rel = RelHeader {
        child: rng.gen_range_u64(16) as u16, // rack index
        epoch: rng.gen_range_u64(4) as u16,
        seq: 1 + rng.gen_range_u64(1 << 20) as u32,
    };
    if rng.gen_bool(0.75) {
        let pairs: Vec<KvPair> = (0..rng.gen_range_usize(40))
            .map(|_| {
                let id = rng.gen_range_u64(1 << 16);
                KvPair::new(
                    Key::from_id(id, 8 + rng.gen_range_usize(57)),
                    rng.gen_range_u64(1000) as i64 - 500,
                )
            })
            .collect();
        Packet::Aggregation(AggregationPacket {
            tree: TreeId(1),
            op: AggOp::Sum,
            eot,
            rel: Some(rel),
            pairs,
        })
    } else {
        let lanes = 1 + rng.gen_range_usize(8);
        let mut batch = VectorBatch::new(lanes);
        let vals: Vec<Value> = (0..lanes).map(|l| l as i64 - 3).collect();
        for _ in 0..rng.gen_range_usize(20) {
            batch.push(Key::from_id(rng.gen_range_u64(1 << 12), 16), &vals);
        }
        Packet::VectorAggregation(VectorAggregationPacket {
            tree: TreeId(1),
            op: AggOp::Sum,
            eot,
            rel: Some(rel),
            batch,
        })
    }
}

/// Relay frames over the full rel × eot × CRC grid: truncation, bit
/// flips, and length inflation must never panic the decoder or let it
/// reserve more rows than the damaged buffer could possibly encode.
#[test]
fn prop_relay_frame_decode_survives_damage() {
    prop("relay decode is total", 300, |rng| {
        for eot in [false, true] {
            for crc in [false, true] {
                let pkt = random_relay_packet(rng, eot);
                let clean = if crc {
                    pkt.encode_integrity()
                } else {
                    pkt.encode()
                };
                let cut = rng.gen_range_usize(clean.len() + 1);
                check_decode_total(&clean[..cut])?;
                let mut flipped = clean.clone();
                for _ in 0..1 + rng.gen_range_usize(8) {
                    let bit = rng.gen_range_usize(flipped.len() * 8);
                    flipped[bit / 8] ^= 1 << (bit % 8);
                }
                check_decode_total(&flipped)?;
                let mut inflated = clean.clone();
                for _ in 0..1 + rng.gen_range_usize(64) {
                    inflated.push(rng.next_u32() as u8);
                }
                check_decode_total(&inflated)?;
            }
        }
        Ok(())
    });
}

/// Exhaustive truncation of one fixed relay frame at *every* prefix,
/// both encodings: the random fuzz samples cut points, this leaves no
/// byte boundary unchecked.
#[test]
fn relay_frame_truncation_is_total_at_every_prefix() {
    let pkt = Packet::Aggregation(AggregationPacket {
        tree: TreeId(1),
        op: AggOp::Sum,
        eot: true,
        rel: Some(RelHeader {
            child: 3,
            epoch: 1,
            seq: 917,
        }),
        pairs: (0..12)
            .map(|i| KvPair::new(Key::from_id(i, 16 + (i % 49) as usize), i as i64 - 6))
            .collect(),
    });
    for crc in [false, true] {
        let buf = if crc {
            pkt.encode_integrity()
        } else {
            pkt.encode()
        };
        for cut in 0..=buf.len() {
            check_decode_total(&buf[..cut])
                .unwrap_or_else(|e| panic!("cut {cut} (crc={crc}): {e}"));
        }
    }
}

/// The RelHeader the spine dedups on must survive both encodings
/// bit-exactly — a child/seq skew would alias distinct relay streams.
#[test]
fn prop_relay_header_roundtrips_through_both_encodings() {
    prop("relay header round-trip", 200, |rng| {
        for eot in [false, true] {
            let pkt = random_relay_packet(rng, eot);
            let want = match &pkt {
                Packet::Aggregation(p) => p.rel,
                Packet::VectorAggregation(p) => p.rel,
                _ => unreachable!(),
            };
            for crc in [false, true] {
                let buf = if crc {
                    pkt.encode_integrity()
                } else {
                    pkt.encode()
                };
                let got = match Packet::decode(&buf) {
                    Ok(Packet::Aggregation(p)) => (p.rel, p.eot),
                    Ok(Packet::VectorAggregation(p)) => (p.rel, p.eot),
                    other => return Err(format!("relay frame decoded as {other:?}")),
                };
                if got != (want, eot) {
                    return Err(format!("rel header skewed: {got:?} vs {want:?}/{eot}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_integrity_trailer_rejects_every_single_bit_flip() {
    prop("CRC catches single flips", 150, |rng| {
        let pkt = match random_packet(rng) {
            // Only the data/ack tags carry the trailer; re-draw others
            // into an Aggregation packet.
            p @ (Packet::Aggregation(_) | Packet::VectorAggregation(_) | Packet::AggAck(_)) => p,
            _ => Packet::Aggregation(AggregationPacket {
                tree: TreeId(7),
                op: AggOp::Sum,
                eot: true,
                rel: None,
                pairs: vec![KvPair::new(Key::from_id(1, 16), 42)],
            }),
        };
        let clean = pkt.encode_integrity();
        if Packet::decode(&clean).is_err() {
            return Err("clean integrity frame failed decode".into());
        }
        let bit = rng.gen_range_usize(clean.len() * 8);
        let mut bad = clean.clone();
        bad[bit / 8] ^= 1 << (bit % 8);
        match Packet::decode(&bad) {
            Err(_) => Ok(()),
            Ok(_) => Err(format!("flip of bit {bit} went undetected")),
        }
    });
}
