//! Integration tests for the MapReduce-like framework: end-to-end jobs
//! on the paper's testbed and beyond, WordCount correctness, and the
//! with/without-SwitchAgg invariants of §6.3.

use switchagg::framework::{run_job, JobSpec, Mapper, Reducer};
use switchagg::net::Topology;
use switchagg::protocol::{AggOp, Key};
use switchagg::switch::SwitchConfig;
use switchagg::workload::corpus::Corpus;
use switchagg::workload::generator::{KeyDist, WorkloadSpec};

fn spec(on: bool) -> JobSpec {
    JobSpec {
        switch_cfg: SwitchConfig::scaled(64 << 10, Some(4 << 20)),
        aggregation_enabled: on,
        op: AggOp::Sum,
    }
}

#[test]
fn wordcount_counts_are_exact() {
    let (topo, _sw, hosts) = Topology::star(4);
    let corpus = Corpus::new(5_000, 77);
    let lines = corpus.lines(512 << 10);
    // Ground truth straight from the text.
    let mut truth = std::collections::HashMap::new();
    for l in &lines {
        for w in l.split_ascii_whitespace() {
            *truth.entry(w.to_string()).or_insert(0i64) += 1;
        }
    }
    let per = lines.len().div_ceil(3);
    let mappers: Vec<Mapper> = lines
        .chunks(per)
        .map(|c| Mapper::WordCount { lines: c.to_vec() })
        .collect();
    let n = mappers.len();
    let (report, merge) = run_job(&topo, &hosts[..n], hosts[3], &mappers, &spec(true)).unwrap();
    assert_eq!(merge.table.len(), truth.len());
    for (w, c) in &truth {
        assert_eq!(merge.table[&Key::new(w.as_bytes())], *c, "word {w}");
    }
    assert!(report.reduction_ratio > 0.0);
}

#[test]
fn aggregation_toggle_does_not_change_results() {
    let (topo, _sw, hosts) = Topology::star(4);
    let mappers: Vec<Mapper> = (0..3)
        .map(|i| {
            Mapper::Synthetic(WorkloadSpec::paper(
                256 << 10,
                64 << 10,
                KeyDist::Zipf(0.99),
                400 + i,
            ))
        })
        .collect();
    let (ra, ma) = run_job(&topo, &hosts[..3], hosts[3], &mappers, &spec(true)).unwrap();
    let (rb, mb) = run_job(&topo, &hosts[..3], hosts[3], &mappers, &spec(false)).unwrap();
    assert_eq!(ma.table, mb.table);
    assert_eq!(ra.result_value_sum, rb.result_value_sum);
    assert_eq!(rb.reduction_ratio, 0.0);
    assert!(ra.reduction_ratio > 0.3);
    assert!(ra.output_bytes < rb.output_bytes);
}

#[test]
fn job_reports_are_internally_consistent() {
    let (topo, _sw, hosts) = Topology::star(4);
    let mappers: Vec<Mapper> = (0..3)
        .map(|i| {
            Mapper::Synthetic(WorkloadSpec::paper(
                128 << 10,
                32 << 10,
                KeyDist::Uniform,
                500 + i,
            ))
        })
        .collect();
    let (r, merge) = run_job(&topo, &hosts[..3], hosts[3], &mappers, &spec(true)).unwrap();
    assert_eq!(r.result_value_sum, r.input_pairs as i64); // all values 1
    assert_eq!(r.result_keys, merge.table.len());
    assert!(r.output_pairs >= merge.table.len() as u64);
    assert!(r.jct.total_s > 0.0 && r.jct_baseline.total_s > 0.0);
    assert!(r.cpu_util > 0.0 && r.cpu_util <= 1.0);
    assert!((0.0..=1.0).contains(&r.reduction_ratio));
    assert!(r.fifo_writes >= r.input_pairs);
}

#[test]
fn software_and_xla_reducers_agree_when_artifacts_present() {
    std::env::set_var(
        "SWITCHAGG_ARTIFACTS",
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"),
    );
    let Ok(engine) = switchagg::runtime::AggEngine::discover() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let streams: Vec<Vec<_>> = (0..2)
        .map(|i| {
            WorkloadSpec::paper(64 << 10, 16 << 10, KeyDist::Zipf(0.99), 600 + i).generate()
        })
        .collect();
    let sw = Reducer::merge_software(&streams, AggOp::Sum);
    let xla = Reducer::merge_xla(&engine, &streams, AggOp::Sum).unwrap();
    assert_eq!(sw.table, xla.table);
}

#[test]
fn two_level_topology_job() {
    let (topo, _spine, _leaves, hosts) = Topology::two_level(2, 2);
    let mappers: Vec<Mapper> = (0..3)
        .map(|i| {
            Mapper::Synthetic(WorkloadSpec::paper(
                64 << 10,
                16 << 10,
                KeyDist::Uniform,
                700 + i,
            ))
        })
        .collect();
    let (r, _) = run_job(&topo, &hosts[..3], hosts[3], &mappers, &spec(true)).unwrap();
    assert_eq!(r.result_value_sum, r.input_pairs as i64);
    assert!(r.reduction_ratio > 0.0);
}

#[test]
fn single_mapper_degenerate_job() {
    let (topo, _sw, hosts) = Topology::star(2);
    let mappers = vec![Mapper::Synthetic(WorkloadSpec::paper(
        32 << 10,
        8 << 10,
        KeyDist::Uniform,
        1,
    ))];
    let (r, _) = run_job(&topo, &hosts[..1], hosts[1], &mappers, &spec(true)).unwrap();
    assert_eq!(r.result_value_sum, r.input_pairs as i64);
}
