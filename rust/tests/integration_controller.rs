//! Integration tests for the control plane: Launch → tree → Configure
//! → Ack over realistic topologies, including failure injection.

use switchagg::controller::{AggTree, Controller};
use switchagg::net::{NodeKind, Topology};
use switchagg::protocol::{AckKind, AggOp, LaunchPacket, Packet, TreeId};
use switchagg::switch::{SwitchAggSwitch, SwitchConfig};

#[test]
fn full_control_plane_handshake_on_two_level_topology() {
    let (topo, _spine, _leaves, hosts) = Topology::two_level(3, 3);
    let mut controller = Controller::new(topo.clone());
    let (mappers, reducer) = (&hosts[..6], hosts[8]);
    let launch = controller
        .launch(
            &LaunchPacket {
                mappers: mappers.iter().map(|m| m.0).collect(),
                reducers: vec![reducer.0],
            },
            AggOp::Sum,
        )
        .unwrap();
    // Configure every switch, ack back; the final ack notifies master.
    let mut master_acked = false;
    let n = launch.configures.len();
    for (i, (sw_node, cfgp)) in launch.configures.iter().enumerate() {
        let mut sw = SwitchAggSwitch::new(SwitchConfig::default());
        sw.configure(&cfgp.trees);
        assert_eq!(sw.n_trees(), 1);
        // The wire round trip of the configure packet.
        let bytes = Packet::Configure(cfgp.clone()).encode();
        assert_eq!(Packet::decode(&bytes).unwrap(), Packet::Configure(cfgp.clone()));
        match controller.switch_ack(launch.tree, *sw_node).unwrap() {
            Some(Packet::Ack(AckKind::Master)) => {
                assert_eq!(i, n - 1, "master ack must come last");
                master_acked = true;
            }
            Some(_) => panic!("unexpected packet"),
            None => assert!(i < n - 1),
        }
    }
    assert!(master_acked);
    assert!(controller.is_running(launch.tree));
}

#[test]
fn tree_children_counts_cover_all_mappers() {
    // Invariant: summing leaf-level mapper children across switches
    // covers every mapper exactly once.
    let (topo, _spine, _leaves, hosts) = Topology::two_level(4, 2);
    let mappers = &hosts[..7];
    let reducer = hosts[7];
    let tree = AggTree::build(&topo, TreeId(5), AggOp::Sum, mappers, reducer).unwrap();
    let mapper_children: usize = tree
        .children
        .values()
        .flatten()
        .filter(|n| topo.kind(**n) == NodeKind::Host)
        .count();
    assert_eq!(mapper_children, mappers.len());
    // Every switch's parent port exists in the topology.
    for (sw, cfg) in &tree.switch_cfgs {
        let found = topo.neighbors(*sw).any(|(p, _)| p == cfg.parent_port);
        assert!(found, "switch {sw} parent port {}", cfg.parent_port);
    }
    // Leaf-to-root order: children of a later switch may include
    // earlier switches, never the reverse.
    for (i, sw) in tree.levels.iter().enumerate() {
        for child in &tree.children[sw] {
            if topo.kind(*child) == NodeKind::Switch {
                let pos = tree.levels.iter().position(|s| s == child).unwrap();
                assert!(pos < i, "child switch after parent in levels");
            }
        }
    }
}

#[test]
fn launch_rejects_bad_requests() {
    let (topo, _sw, hosts) = Topology::star(4);
    let mut c = Controller::new(topo);
    // No mappers.
    assert!(c
        .launch(
            &LaunchPacket {
                mappers: vec![],
                reducers: vec![hosts[0].0]
            },
            AggOp::Sum
        )
        .is_err());
    // Reducer that is a switch (node 0 in a star).
    assert!(c
        .launch(
            &LaunchPacket {
                mappers: vec![hosts[0].0],
                reducers: vec![0]
            },
            AggOp::Sum
        )
        .is_err());
}

#[test]
fn concurrent_trees_share_switches() {
    let (topo, _sw, hosts) = Topology::star(4);
    let mut c = Controller::new(topo);
    let l1 = c
        .launch(
            &LaunchPacket {
                mappers: vec![hosts[0].0, hosts[1].0],
                reducers: vec![hosts[3].0],
            },
            AggOp::Sum,
        )
        .unwrap();
    let l2 = c
        .launch(
            &LaunchPacket {
                mappers: vec![hosts[1].0, hosts[2].0],
                reducers: vec![hosts[0].0],
            },
            AggOp::Max,
        )
        .unwrap();
    assert_ne!(l1.tree, l2.tree);
    // One physical switch carries both trees.
    let mut sw = SwitchAggSwitch::new(SwitchConfig::default());
    sw.configure(&l1.configures[0].1.trees);
    sw.configure(&l2.configures[0].1.trees);
    assert_eq!(sw.n_trees(), 2);
}

#[test]
fn teardown_releases_tree_state() {
    let (topo, _sw, hosts) = Topology::star(3);
    let mut c = Controller::new(topo);
    let l = c
        .launch(
            &LaunchPacket {
                mappers: vec![hosts[0].0],
                reducers: vec![hosts[2].0],
            },
            AggOp::Sum,
        )
        .unwrap();
    assert!(c.tree(l.tree).is_some());
    assert!(c.teardown(l.tree));
    assert!(c.tree(l.tree).is_none());
    // Acks for a torn-down tree are failures, not panics.
    let sw_node = l.configures[0].0;
    assert!(c.switch_ack(l.tree, sw_node).is_err());
}
