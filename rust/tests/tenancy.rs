//! Incremental reconfiguration under tenant churn — the multi-tenant
//! tentpole's differential contract:
//!
//! A resident tenant's aggregation must be **byte-identical** to a
//! solo run of the same tenant while neighbor trees are admitted,
//! ingested into, idled, reclaimed, and evicted around it.  Identical
//! means the strongest observable form: the exact emitted pair
//! sequences (stream order and flush order, not just the merged
//! totals), the full `SwitchStats` debug state, the dedup-window
//! stats, and the epoch register — across two epoch-fenced jobs with a
//! stale-epoch straggler pinned in both runs.
//!
//! Swept over the serial and sharded execution engines × lane widths
//! W ∈ {1, 8} (scalar resident and vector resident; churn neighbors
//! stay scalar, which also pins scalar/vector tenant coexistence).

use std::collections::BTreeMap;
use switchagg::protocol::{
    AggOp, AggregationPacket, Key, KvPair, RelHeader, TreeConfig, TreeId,
    VectorAggregationPacket, VectorBatch, VectorChunks,
};
use switchagg::switch::{
    IngestSink, Parallelism, QuotaRequest, SwitchAggSwitch, SwitchConfig, VectorSink,
};
use switchagg::util::rng::Pcg32;

const RESIDENT: TreeId = TreeId(1);

/// Sequence-stamp a packet run (the crate-private `reliable::stamp`,
/// restated for this out-of-crate test).
fn stamp<P>(pkts: &mut [P], child: u16, epoch: u16, set: impl Fn(&mut P, RelHeader)) {
    for (i, p) in pkts.iter_mut().enumerate() {
        set(
            p,
            RelHeader {
                child,
                epoch,
                seq: i as u32 + 1,
            },
        );
    }
}

fn switch_cfg(par: Parallelism) -> SwitchConfig {
    SwitchConfig {
        parallelism: par,
        ..SwitchConfig::scaled(32 << 10, Some(512 << 10))
    }
}

fn tc(id: u32, children: u16) -> TreeConfig {
    TreeConfig {
        tree: TreeId(id),
        children,
        parent_port: 0,
        op: AggOp::Sum,
    }
}

fn resident_quota(cfg: &SwitchConfig, lanes: usize) -> QuotaRequest {
    QuotaRequest {
        fpe_bytes: (cfg.fpe_total_mem / 4).max(cfg.min_fpe_share(lanes)),
        bpe_bytes: cfg.bpe_mem.unwrap_or(0) / 4,
    }
}

fn neighbor_quota(cfg: &SwitchConfig) -> QuotaRequest {
    QuotaRequest {
        fpe_bytes: (cfg.fpe_total_mem / 16).max(cfg.min_fpe_share(1)),
        bpe_bytes: cfg.bpe_mem.unwrap_or(0) / 16,
    }
}

fn random_pairs(rng: &mut Pcg32, n: usize, variety: u64) -> Vec<KvPair> {
    (0..n)
        .map(|_| {
            let id = rng.gen_range_u64(variety);
            KvPair::new(
                Key::from_id(id, 16 + (id % 49) as usize),
                rng.gen_range_u64(200) as i64 - 100,
            )
        })
        .collect()
}

/// Resident job: per-child scalar packets for epoch `epoch`, stamped.
fn scalar_job(children: u16, epoch: u16, seed: u64) -> Vec<Vec<AggregationPacket>> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|c| {
            let stream = random_pairs(&mut rng, 300, 80);
            let mut v = AggregationPacket::pack_stream(RESIDENT, AggOp::Sum, &stream, true);
            stamp(&mut v, c, epoch, |p, rel| p.rel = Some(rel));
            v
        })
        .collect()
}

fn vector_job(children: u16, lanes: usize, epoch: u16, seed: u64) -> Vec<Vec<VectorAggregationPacket>> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|c| {
            let mut batch = VectorBatch::new(lanes);
            let mut vals = vec![0i64; lanes];
            for _ in 0..300 {
                let id = rng.gen_range_u64(80);
                for (l, v) in vals.iter_mut().enumerate() {
                    *v = (id % 17) as i64 + l as i64 - 8;
                }
                batch.push(Key::from_id(id, 16 + (id % 49) as usize), &vals);
            }
            let mut out = Vec::new();
            let mut chunks = VectorChunks::new(&batch);
            while let Some((range, last)) = chunks.next_chunk() {
                out.push(VectorAggregationPacket {
                    tree: RESIDENT,
                    op: AggOp::Sum,
                    eot: last,
                    rel: None,
                    batch: batch.sub_batch(range),
                });
            }
            stamp(&mut out, c, epoch, |p, rel| p.rel = Some(rel));
            out
        })
        .collect()
}

/// Flatten per-child packet lists into the round-robin ingest order
/// both runs share.
fn round_robin<P: Clone>(pkts: &[Vec<P>]) -> Vec<P> {
    let mut out = Vec::new();
    let longest = pkts.iter().map(|v| v.len()).max().unwrap_or(0);
    for i in 0..longest {
        for child in pkts {
            if let Some(p) = child.get(i) {
                out.push(p.clone());
            }
        }
    }
    out
}

/// Random neighbor churn around the resident: admissions (some over
/// quota → typed rejection or elastic reclaim of idled neighbors),
/// scalar ingest into live neighbors, idling, eviction.  Entirely
/// driven by `rng`, so solo-vs-churn runs differ *only* in whether
/// this is called.
struct Churn {
    rng: Pcg32,
    next_id: u32,
    live: Vec<TreeId>,
    pkts: BTreeMap<TreeId, (Vec<AggregationPacket>, usize)>,
    sinks: BTreeMap<TreeId, IngestSink>,
    admitted: u32,
    rejected: u32,
    evicted: u32,
}

impl Churn {
    fn new(seed: u64) -> Self {
        Self {
            rng: Pcg32::new(seed),
            next_id: 100,
            live: Vec::new(),
            pkts: BTreeMap::new(),
            sinks: BTreeMap::new(),
            admitted: 0,
            rejected: 0,
            evicted: 0,
        }
    }

    fn cycle(&mut self, sw: &mut SwitchAggSwitch) {
        for _ in 0..3 {
            match self.rng.gen_range_u64(4) {
                0 => self.admit(sw),
                1 => self.ingest_one(sw),
                2 => self.evict(sw),
                _ => self.idle_one(sw),
            }
        }
    }

    fn admit(&mut self, sw: &mut SwitchAggSwitch) {
        let id = self.next_id;
        self.next_id += 1;
        let children = 1 + (self.rng.gen_range_u64(3) as u16);
        let q = neighbor_quota(sw.config());
        let tree = TreeId(id);
        // `Ok` alone is not residency: the reclaim path may shrink
        // neighbors yet still fail admission (degraded Ok).
        let _ = sw.admit_tree_or_reclaim(tc(id, children), q, 1);
        if sw.stats(tree).is_none() {
            self.rejected += 1;
            return;
        }
        self.admitted += 1;
        let stream = random_pairs(&mut self.rng, 40, 24);
        let mut v = AggregationPacket::pack_stream(tree, AggOp::Sum, &stream, true);
        stamp(&mut v, 0, 0, |p, rel| p.rel = Some(rel));
        self.live.push(tree);
        self.pkts.insert(tree, (v, 0));
        self.sinks.insert(tree, IngestSink::new());
    }

    fn ingest_one(&mut self, sw: &mut SwitchAggSwitch) {
        if self.live.is_empty() {
            return;
        }
        let tree = self.live[self.rng.gen_range_u64(self.live.len() as u64) as usize];
        let (pkts, at) = self.pkts.get_mut(&tree).expect("live neighbor packets");
        if *at >= pkts.len() {
            return;
        }
        let sink = self.sinks.get_mut(&tree).expect("live neighbor sink");
        sw.ingest_reliable_one(tree, &pkts[*at], sink);
        *at += 1;
    }

    fn evict(&mut self, sw: &mut SwitchAggSwitch) {
        if self.live.is_empty() {
            return;
        }
        let i = self.rng.gen_range_u64(self.live.len() as u64) as usize;
        let tree = self.live.swap_remove(i);
        assert!(sw.evict_tree(tree).is_some(), "evicting a live neighbor");
        self.pkts.remove(&tree);
        self.sinks.remove(&tree);
        self.evicted += 1;
    }

    fn idle_one(&mut self, sw: &mut SwitchAggSwitch) {
        if self.live.is_empty() {
            return;
        }
        let tree = self.live[self.rng.gen_range_u64(self.live.len() as u64) as usize];
        sw.set_tenant_idle(tree, true);
    }
}

/// Everything the resident exposes, in its strongest comparable form.
#[derive(Debug, PartialEq)]
struct ResidentSnapshot {
    forwarded: Vec<KvPair>,
    flushed_a: Vec<KvPair>,
    flushed_b: Vec<KvPair>,
    stats: String,
    dedup: String,
}

/// Drive the scalar resident through two epoch-fenced jobs (plus one
/// stale-epoch straggler), optionally churning neighbors between every
/// resident packet.
fn scalar_resident_run(par: Parallelism, churn: bool) -> ResidentSnapshot {
    let cfg = switch_cfg(par);
    let q = resident_quota(&cfg, 1);
    let mut sw = SwitchAggSwitch::new(cfg);
    sw.admit_tree(tc(1, 2), q, 8).expect("resident admission");
    sw.set_tenant_idle(RESIDENT, false);
    let mut churner = Churn::new(0xC1C1);

    let job_a = round_robin(&scalar_job(2, 0, 0xA11CE));
    let job_b = round_robin(&scalar_job(2, 1, 0xB0B));
    let mut sink = IngestSink::new();

    for pkt in &job_a {
        sw.ingest_reliable_one(RESIDENT, pkt, &mut sink);
        if churn {
            churner.cycle(&mut sw);
        }
    }
    assert_eq!(sink.flushes, 1);
    sw.finalize(RESIDENT);
    let forwarded = sink.forwarded.clone();
    let flushed_a = sink.flushed.clone();
    sink.clear();

    // Job B behind an epoch fence; replay one job-A packet as a stale
    // straggler — it must be dropped and counted in BOTH runs.
    sw.begin_epoch(RESIDENT, 1);
    sw.ingest_reliable_one(RESIDENT, &job_a[0], &mut sink);
    for pkt in &job_b {
        sw.ingest_reliable_one(RESIDENT, pkt, &mut sink);
        if churn {
            churner.cycle(&mut sw);
        }
    }
    assert_eq!(sink.flushes, 1);
    sw.finalize(RESIDENT);

    if churn {
        assert!(churner.admitted >= 5, "churn actually churned: {}", churner.admitted);
        assert!(churner.evicted >= 2, "churn actually evicted: {}", churner.evicted);
    }
    let dedup = sw.dedup_stats(RESIDENT);
    assert_eq!(dedup.stale_epoch_drops, 1, "the straggler was fenced");
    ResidentSnapshot {
        forwarded: {
            let mut f = forwarded;
            f.extend_from_slice(&sink.forwarded);
            f
        },
        flushed_a,
        flushed_b: sink.flushed.clone(),
        stats: format!("{:?}", sw.stats(RESIDENT).expect("resident stats")),
        dedup: format!("{:?}", dedup),
    }
}

/// The W-lane counterpart: vector resident, scalar churn neighbors.
fn vector_resident_run(par: Parallelism, lanes: usize, churn: bool) -> (VectorBatch, VectorBatch, String, String) {
    let cfg = switch_cfg(par);
    let q = resident_quota(&cfg, lanes);
    let mut sw = SwitchAggSwitch::new(cfg);
    sw.admit_tree_lanes(tc(1, 2), q, 8, lanes).expect("resident admission");
    sw.set_tenant_idle(RESIDENT, false);
    let mut churner = Churn::new(0xC2C2);

    let job = round_robin(&vector_job(2, lanes, 0, 0xFEED));
    let mut sink = VectorSink::new(lanes);
    for pkt in &job {
        sw.ingest_vector_reliable_one(RESIDENT, pkt, &mut sink);
        if churn {
            churner.cycle(&mut sw);
        }
    }
    assert_eq!(sink.flushes, 1);
    sw.finalize(RESIDENT);
    if churn {
        assert!(churner.admitted >= 5, "churn actually churned: {}", churner.admitted);
    }
    (
        sink.forwarded.clone(),
        sink.flushed.clone(),
        format!("{:?}", sw.stats(RESIDENT).expect("resident stats")),
        format!("{:?}", sw.dedup_stats(RESIDENT)),
    )
}

/// The tentpole differential: solo == churned, byte for byte, on both
/// engines.
#[test]
fn scalar_resident_is_byte_identical_across_neighbor_churn() {
    for par in [Parallelism::Serial, Parallelism::Sharded(4)] {
        let solo = scalar_resident_run(par, false);
        let churned = scalar_resident_run(par, true);
        assert_eq!(
            solo, churned,
            "{par:?}: churn perturbed the resident's state"
        );
    }
}

/// And the W = 8 vector resident, with scalar neighbors churning.
#[test]
fn vector_resident_is_byte_identical_across_neighbor_churn() {
    for par in [Parallelism::Serial, Parallelism::Sharded(4)] {
        let solo = vector_resident_run(par, 8, false);
        let churned = vector_resident_run(par, 8, true);
        assert_eq!(
            solo, churned,
            "{par:?}: churn perturbed the vector resident's state"
        );
    }
}

/// The same switch state is reached no matter the engine: the solo
/// snapshots of Serial and Sharded runs agree (stats carry engine-
/// invariant counters only by contract — pinned here for tenants).
#[test]
fn resident_snapshot_is_engine_invariant() {
    let a = scalar_resident_run(Parallelism::Serial, true);
    let b = scalar_resident_run(Parallelism::Sharded(4), true);
    assert_eq!(a.forwarded, b.forwarded);
    assert_eq!(a.flushed_a, b.flushed_a);
    assert_eq!(a.flushed_b, b.flushed_b);
    assert_eq!(a.dedup, b.dedup);
}

/// Admission after eviction reuses the id with a clean slate: the
/// second incarnation of a tree id sees no dedup ghosts.
#[test]
fn readmission_starts_with_a_clean_dedup_window() {
    let cfg = switch_cfg(Parallelism::Serial);
    let q = resident_quota(&cfg, 1);
    let mut sw = SwitchAggSwitch::new(cfg);
    sw.admit_tree(tc(1, 1), q, 1).unwrap();
    let pkts = round_robin(&scalar_job(1, 0, 0x5EED));
    let mut sink = IngestSink::new();
    for p in &pkts {
        sw.ingest_reliable_one(RESIDENT, p, &mut sink);
    }
    sw.finalize(RESIDENT);
    let first = sink.flushed.clone();
    assert!(sw.evict_tree(RESIDENT).is_some());

    // Same packets, same id, fresh incarnation: everything admitted
    // anew (a stale window would dedup-drop the whole replay).
    sw.admit_tree(tc(1, 1), q, 1).unwrap();
    sink.clear();
    for p in &pkts {
        sw.ingest_reliable_one(RESIDENT, p, &mut sink);
    }
    sw.finalize(RESIDENT);
    assert_eq!(sink.flushes, 1);
    assert_eq!(sink.flushed, first, "the re-admitted tenant reruns the job exactly");
}
