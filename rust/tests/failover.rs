//! Warm-standby failover — integration contracts:
//!
//! * **Snapshot round-trip is byte-deterministic** — snapshot a switch
//!   mid-ingest at a random prefix, restore into a fresh switch, feed
//!   both the identical suffix: every emission and the *entire*
//!   serialized end state (engine, stats, dedup windows) are
//!   byte-identical to the uncrashed switch.  Scalar and W-lane vector
//!   (W ∈ {1, 8}), serial and sharded engines.
//! * **Zero-fault transparency** — a failover session with no standby
//!   and an empty plan is byte-identical to the plain transport
//!   session it wraps (stream, per-hop stats, JCT).
//! * **Mid-job promotion is the same job** — a fail-stop primary with
//!   a checkpointed standby finishes in-network with the reducer
//!   stream byte-identical to the fault-free session's, lossless and
//!   lossy, scalar and vector.
//! * **Decode robustness** — snapshot and delta decoding must survive
//!   truncation at every prefix, random bit flips, and length
//!   inflation without panicking (a hostile or half-written checkpoint
//!   can reach `restore_tree` unvalidated).

use std::collections::HashMap;
use switchagg::framework::failover::{
    run_failover_scalar, run_failover_vector, FailoverConfig,
};
use switchagg::framework::transport::{
    run_transport_scalar, run_transport_vector, TransportConfig,
};
use switchagg::framework::Reducer;
use switchagg::net::FaultPlan;
use switchagg::protocol::{
    AggOp, AggregationPacket, Key, KvPair, RelHeader, TreeConfig, TreeId, Value, VectorBatch,
};
use switchagg::switch::{
    vector_sink_to_batch, IngestSink, Parallelism, SnapshotDelta, SwitchAggSwitch, SwitchConfig,
    SwitchSnapshot, VectorSink,
};
use switchagg::util::rng::Pcg32;

fn switch_cfg(par: Parallelism) -> SwitchConfig {
    SwitchConfig {
        parallelism: par,
        ..SwitchConfig::scaled(16 << 10, Some(256 << 10))
    }
}

fn configured(children: u16, par: Parallelism, lanes: usize) -> SwitchAggSwitch {
    let mut sw = SwitchAggSwitch::new(switch_cfg(par));
    sw.configure_vector(
        &[TreeConfig {
            tree: TreeId(1),
            children,
            parent_port: 0,
            op: AggOp::Sum,
        }],
        lanes,
    );
    sw
}

fn scalar_streams(children: usize, n: usize, seed: u64) -> Vec<Vec<KvPair>> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0x77);
            (0..n)
                .map(|_| {
                    let id = child.gen_range_u64(300);
                    KvPair::new(
                        Key::from_id(id, 16 + (id % 49) as usize),
                        child.gen_range_u64(200) as i64 - 100,
                    )
                })
                .collect()
        })
        .collect()
}

/// Scalar streams opening with one fixed pass over the full key set:
/// the table layout is frozen within the first few % of the job, which
/// is what makes a mid-job promotion's replay land byte-identically
/// (see `framework::failover`'s module doc).
fn replayable_scalar_streams(children: usize, n: usize, seed: u64) -> Vec<Vec<KvPair>> {
    let keys = 32u64;
    let key = |id: u64| Key::from_id(id, 16 + (id % 49) as usize);
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut s: Vec<KvPair> = (0..keys).map(|id| KvPair::new(key(id), 1)).collect();
            for _ in keys as usize..n {
                let id = rng.gen_range_u64(keys);
                s.push(KvPair::new(key(id), rng.gen_range_u64(9) as i64 - 4));
            }
            s
        })
        .collect()
}

fn replayable_vector_streams(
    children: usize,
    n: usize,
    lanes: usize,
    seed: u64,
) -> Vec<VectorBatch> {
    let keys = 24u64;
    let key = |id: u64| Key::from_id(id, 16 + (id % 49) as usize);
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut b = VectorBatch::new(lanes);
            let mut vals: Vec<Value> = vec![0; lanes];
            for id in 0..keys {
                for (l, v) in vals.iter_mut().enumerate() {
                    *v = 1 + l as i64;
                }
                b.push(key(id), &vals);
            }
            for _ in keys as usize..n {
                let id = rng.gen_range_u64(keys);
                for v in vals.iter_mut() {
                    *v = rng.gen_range_u64(9) as i64 - 4;
                }
                b.push(key(id), &vals);
            }
            b
        })
        .collect()
}

fn merged(pairs: &[KvPair]) -> HashMap<Key, Value> {
    Reducer::merge_software(&[pairs.to_vec()], AggOp::Sum).table
}

fn merged_streams(streams: &[Vec<KvPair>]) -> HashMap<Key, Value> {
    Reducer::merge_software(streams, AggOp::Sum).table
}

fn stamped(tree: TreeId, stream: &[KvPair], child: u16) -> Vec<AggregationPacket> {
    let mut v = AggregationPacket::pack_stream(tree, AggOp::Sum, stream, true);
    for (i, p) in v.iter_mut().enumerate() {
        p.rel = Some(RelHeader {
            child,
            epoch: 0,
            seq: i as u32 + 1,
        });
    }
    v
}

// --- Snapshot round-trip ---------------------------------------------

/// Drive one scalar tree through (prefix | snapshot+restore | suffix)
/// and assert the restored switch is indistinguishable — emissions,
/// final serialized state, dedup counters, recovered totals.
fn scalar_round_trip(par: Parallelism, split_seed: u64) {
    let tree = TreeId(1);
    let children = 3usize;
    let ss = scalar_streams(children, 500, 0xA0 ^ split_seed);
    let pkts: Vec<Vec<AggregationPacket>> = ss
        .iter()
        .enumerate()
        .map(|(c, s)| stamped(tree, s, c as u16))
        .collect();
    // Random split, but each child's EoT packet stays in the suffix so
    // the one flush of the job is exercised on the *restored* switch.
    let mut rng = Pcg32::new(split_seed);
    let splits: Vec<usize> = pkts
        .iter()
        .map(|v| rng.gen_range_u64(v.len() as u64) as usize)
        .collect();

    // The uncrashed switch ingests everything in one life.
    let mut live = configured(children as u16, par, 1);
    let mut live_sink = IngestSink::new();
    // The crashed path: prefix on the primary, suffix on the restored.
    let mut primary = configured(children as u16, par, 1);
    let mut pre_sink = IngestSink::new();

    for (c, v) in pkts.iter().enumerate() {
        for p in &v[..splits[c]] {
            live.ingest_reliable_one(tree, p, &mut live_sink);
            primary.ingest_reliable_one(tree, p, &mut pre_sink);
        }
    }
    let snap = primary.snapshot_tree(tree).expect("resident tree snapshots");
    let bytes = snap.to_bytes();
    let decoded = SwitchSnapshot::from_bytes(&bytes).expect("own encoding decodes");

    let mut restored = configured(children as u16, par, 1);
    assert_eq!(
        restored.restore_tree(&decoded).expect("restore"),
        tree,
        "{par:?}: restore reports the snapshotted tree"
    );
    // Restore → snapshot is the identity on the serialized state.
    assert_eq!(
        restored
            .snapshot_tree(tree)
            .expect("restored tree snapshots")
            .to_bytes(),
        bytes,
        "{par:?}: snapshot/restore round-trip is byte-exact"
    );

    let mut post_sink = IngestSink::new();
    for (c, v) in pkts.iter().enumerate() {
        for p in &v[splits[c]..] {
            live.ingest_reliable_one(tree, p, &mut live_sink);
            restored.ingest_reliable_one(tree, p, &mut post_sink);
        }
    }
    // Suffix emissions match the live switch's suffix emissions.
    assert_eq!(live_sink.flushes, 1, "{par:?}");
    assert_eq!(post_sink.flushes, 1, "{par:?}");
    assert_eq!(
        post_sink.forwarded,
        live_sink.forwarded[pre_sink.forwarded.len()..].to_vec(),
        "{par:?}: post-restore stream emissions"
    );
    assert_eq!(post_sink.flushed, live_sink.flushed, "{par:?}: flush output");
    // The full end state — engine layout, stats counters, dedup
    // windows — serializes byte-identically (SwitchStats is not
    // directly comparable; its serialized form is, which is stronger).
    live.finalize(tree);
    restored.finalize(tree);
    assert_eq!(
        restored.snapshot_tree(tree).expect("snap").to_bytes(),
        live.snapshot_tree(tree).expect("snap").to_bytes(),
        "{par:?}: end states are byte-identical"
    );
    assert_eq!(restored.dedup_stats(tree), live.dedup_stats(tree), "{par:?}");
    let mut total: Vec<KvPair> = post_sink.forwarded.clone();
    total.extend_from_slice(&pre_sink.forwarded);
    total.extend_from_slice(&post_sink.flushed);
    assert_eq!(merged(&total), merged_streams(&ss), "{par:?}: recovered totals");
}

#[test]
fn scalar_snapshot_round_trip_is_byte_exact_at_random_prefixes() {
    for par in [Parallelism::Serial, Parallelism::Sharded(2)] {
        for seed in [1u64, 2, 3] {
            scalar_round_trip(par, seed);
        }
    }
}

/// The W-lane vector counterpart of [`scalar_round_trip`].
fn vector_round_trip(par: Parallelism, lanes: usize, split_seed: u64) {
    let tree = TreeId(1);
    let children = 3usize;
    let ss = replayable_vector_streams(children, 400, lanes, 0xB0 ^ split_seed);
    let pkts: Vec<Vec<switchagg::protocol::VectorAggregationPacket>> = ss
        .iter()
        .enumerate()
        .map(|(c, b)| {
            let mut out = Vec::new();
            let mut chunks = switchagg::protocol::VectorChunks::new(b);
            let mut seq = 0u32;
            while let Some((range, last)) = chunks.next_chunk() {
                seq += 1;
                out.push(switchagg::protocol::VectorAggregationPacket {
                    tree,
                    op: AggOp::Sum,
                    eot: last,
                    rel: Some(RelHeader {
                        child: c as u16,
                        epoch: 0,
                        seq,
                    }),
                    batch: b.sub_batch(range),
                });
            }
            out
        })
        .collect();
    let mut rng = Pcg32::new(split_seed);
    let splits: Vec<usize> = pkts
        .iter()
        .map(|v| rng.gen_range_u64(v.len() as u64) as usize)
        .collect();

    let mut live = configured(children as u16, par, lanes);
    let mut live_sink = VectorSink::new(lanes);
    let mut primary = configured(children as u16, par, lanes);
    let mut pre_sink = VectorSink::new(lanes);

    for (c, v) in pkts.iter().enumerate() {
        for p in &v[..splits[c]] {
            live.ingest_vector_reliable_one(tree, p, &mut live_sink);
            primary.ingest_vector_reliable_one(tree, p, &mut pre_sink);
        }
    }
    let bytes = primary.snapshot_tree(tree).expect("snapshot").to_bytes();
    let decoded = SwitchSnapshot::from_bytes(&bytes).expect("decodes");
    let mut restored = configured(children as u16, par, lanes);
    restored.restore_tree(&decoded).expect("restore");
    assert_eq!(
        restored.snapshot_tree(tree).expect("snap").to_bytes(),
        bytes,
        "W={lanes} {par:?}: round-trip"
    );

    let mut post_sink = VectorSink::new(lanes);
    for (c, v) in pkts.iter().enumerate() {
        for p in &v[splits[c]..] {
            live.ingest_vector_reliable_one(tree, p, &mut live_sink);
            restored.ingest_vector_reliable_one(tree, p, &mut post_sink);
        }
    }
    assert_eq!(live_sink.flushes, 1, "W={lanes} {par:?}");
    assert_eq!(post_sink.flushes, 1, "W={lanes} {par:?}");
    let live_suffix = live_sink
        .forwarded
        .sub_batch(pre_sink.forwarded.len()..live_sink.forwarded.len());
    assert_eq!(
        post_sink.forwarded, live_suffix,
        "W={lanes} {par:?}: post-restore stream emissions"
    );
    assert_eq!(
        post_sink.flushed, live_sink.flushed,
        "W={lanes} {par:?}: flush output"
    );
    live.finalize(tree);
    restored.finalize(tree);
    assert_eq!(
        restored.snapshot_tree(tree).expect("snap").to_bytes(),
        live.snapshot_tree(tree).expect("snap").to_bytes(),
        "W={lanes} {par:?}: end states"
    );
    // Silence the "built but unused" lint path on vector_sink_to_batch
    // while also pinning emission-order concatenation.
    assert_eq!(
        vector_sink_to_batch(&post_sink).len(),
        post_sink.forwarded.len() + post_sink.flushed.len()
    );
}

#[test]
fn vector_snapshot_round_trip_is_byte_exact_at_random_prefixes() {
    for par in [Parallelism::Serial, Parallelism::Sharded(2)] {
        for lanes in [1usize, 8] {
            vector_round_trip(par, lanes, 5);
        }
    }
}

// --- Zero-fault transparency -----------------------------------------

#[test]
fn zero_fault_failover_session_is_byte_identical_to_plain_transport() {
    let ss = scalar_streams(4, 700, 0xC1);
    for tcfg in [TransportConfig::default(), TransportConfig::uniform(0.03, 41)] {
        let cfg = FailoverConfig {
            transport: tcfg,
            ..FailoverConfig::default()
        };
        let fo = run_failover_scalar(&switch_cfg(Parallelism::Serial), AggOp::Sum, &ss, &cfg)
            .expect("fault-free failover session");
        let mut sw = configured(4, Parallelism::Serial, 1);
        let plain = run_transport_scalar(&mut sw, TreeId(1), AggOp::Sum, &ss, &cfg.transport);
        assert_eq!(fo.received, plain.received, "reducer stream");
        assert_eq!(fo.ingress, plain.ingress, "ingress hop stats");
        assert_eq!(fo.egress, plain.egress, "egress hop stats");
        assert_eq!(fo.dedup, plain.dedup, "dedup counters");
        assert_eq!(fo.jct_s, plain.jct_s, "bit-identical JCT");
        assert_eq!(fo.fifo_peak, plain.fifo_peak);
        assert!(!fo.promoted && !fo.degraded && fo.faulted_drops == 0);
    }
}

#[test]
fn zero_fault_failover_vector_session_matches_plain_transport() {
    for lanes in [1usize, 8] {
        let ss = replayable_vector_streams(3, 400, lanes, 0xC2);
        let cfg = FailoverConfig::default();
        let fo = run_failover_vector(&switch_cfg(Parallelism::Serial), AggOp::Sum, &ss, &cfg)
            .expect("fault-free vector session");
        let mut sw = configured(3, Parallelism::Serial, lanes);
        let plain = run_transport_vector(&mut sw, TreeId(1), AggOp::Sum, &ss, &cfg.transport);
        assert_eq!(fo.received, plain.received, "W={lanes}: reducer batch");
        assert_eq!(fo.ingress, plain.ingress, "W={lanes}");
        assert_eq!(fo.egress, plain.egress, "W={lanes}");
        assert_eq!(fo.jct_s, plain.jct_s, "W={lanes}");
    }
}

// --- Mid-job promotion differential ----------------------------------

#[test]
fn mid_job_promotion_is_byte_identical_to_the_fault_free_session_scalar() {
    let ss = replayable_scalar_streams(4, 360, 0xD1);
    let scfg = switch_cfg(Parallelism::Serial);
    for tcfg in [TransportConfig::default(), TransportConfig::uniform(0.02, 43)] {
        let base = run_failover_scalar(
            &scfg,
            AggOp::Sum,
            &ss,
            &FailoverConfig {
                transport: tcfg,
                ..FailoverConfig::default()
            },
        )
        .expect("fault-free");
        // The fault-free failover session IS the plain transport
        // session (transparency above), so pinning against it pins
        // against the plain session too.
        let cfg = FailoverConfig {
            transport: tcfg,
            plan: FaultPlan::none().with_switch_crash(base.jct_s * 0.55, None),
            standby: true,
            checkpoint_period_s: Some(base.jct_s * 0.2),
            max_retries: Some(6),
            ..FailoverConfig::default()
        };
        let fo = run_failover_scalar(&scfg, AggOp::Sum, &ss, &cfg).expect("promotes");
        assert!(fo.promoted && !fo.degraded);
        assert_eq!(fo.final_epoch, 1, "promotion bumps the epoch once");
        assert!(fo.checkpoints_installed >= 1, "warm state was installed");
        assert!(fo.faulted_drops > 0, "the outage must actually bite");
        assert!(
            fo.replayed_packets > 0 && fo.replayed_packets < fo.ingress.first_tx,
            "replay is real but bounded by the checkpoint: {} of {}",
            fo.replayed_packets,
            fo.ingress.first_tx
        );
        assert_eq!(
            fo.received, base.received,
            "promotion must reproduce the fault-free reducer stream byte-for-byte"
        );
        assert_eq!(merged(&fo.received), merged_streams(&ss));
        assert!(fo.jct_s > base.jct_s, "a mid-job outage cannot be free");
    }
}

#[test]
fn mid_job_promotion_is_byte_identical_to_the_fault_free_session_vector() {
    let lanes = 8;
    let ss = replayable_vector_streams(3, 320, lanes, 0xD2);
    let scfg = switch_cfg(Parallelism::Serial);
    let base = run_failover_vector(&scfg, AggOp::Sum, &ss, &FailoverConfig::default())
        .expect("fault-free");
    let cfg = FailoverConfig {
        plan: FaultPlan::none().with_switch_crash(base.jct_s * 0.55, None),
        standby: true,
        checkpoint_period_s: Some(base.jct_s * 0.2),
        max_retries: Some(6),
        ..FailoverConfig::default()
    };
    let fo = run_failover_vector(&scfg, AggOp::Sum, &ss, &cfg).expect("promotes");
    assert!(fo.promoted && !fo.degraded);
    assert_eq!(fo.received, base.received, "W={lanes} vector promotion");
}

#[test]
fn promotion_is_engine_invariant() {
    let ss = replayable_scalar_streams(4, 360, 0xD3);
    let base = run_failover_scalar(
        &switch_cfg(Parallelism::Serial),
        AggOp::Sum,
        &ss,
        &FailoverConfig::default(),
    )
    .expect("fault-free");
    let cfg = FailoverConfig {
        plan: FaultPlan::none().with_switch_crash(base.jct_s * 0.55, None),
        standby: true,
        checkpoint_period_s: Some(base.jct_s * 0.2),
        max_retries: Some(6),
        ..FailoverConfig::default()
    };
    let a = run_failover_scalar(&switch_cfg(Parallelism::Serial), AggOp::Sum, &ss, &cfg)
        .expect("serial");
    let b = run_failover_scalar(&switch_cfg(Parallelism::Sharded(2)), AggOp::Sum, &ss, &cfg)
        .expect("sharded");
    assert_eq!(a.received, b.received);
    assert_eq!(a.ingress, b.ingress);
    assert_eq!(a.replayed_packets, b.replayed_packets);
    assert_eq!(a.checkpoint_bytes, b.checkpoint_bytes);
    assert_eq!(a.jct_s, b.jct_s);
}

// --- Decode robustness ------------------------------------------------

/// A populated snapshot (and a delta against a mutated successor) to
/// fuzz against — real sections, non-trivial geometry.
fn fuzz_corpus() -> (Vec<u8>, Vec<u8>) {
    let tree = TreeId(1);
    let mut sw = configured(2, Parallelism::Serial, 1);
    let ss = scalar_streams(2, 300, 0xE0);
    let mut sink = IngestSink::new();
    let pkts: Vec<Vec<AggregationPacket>> = ss
        .iter()
        .enumerate()
        .map(|(c, s)| stamped(tree, s, c as u16))
        .collect();
    for p in &pkts[0] {
        sw.ingest_reliable_one(tree, p, &mut sink);
    }
    let prev = sw.snapshot_tree(tree).expect("snapshot");
    for p in &pkts[1] {
        sw.ingest_reliable_one(tree, p, &mut sink);
    }
    let next = sw.snapshot_tree(tree).expect("snapshot");
    let delta = SnapshotDelta::between(0, &prev, &next);
    assert!(!delta.is_empty(), "the suffix must dirty some region");
    (next.to_bytes(), delta.to_bytes())
}

#[test]
fn snapshot_decode_survives_truncation_at_every_prefix() {
    let (snap, delta) = fuzz_corpus();
    for cut in 0..snap.len() {
        assert!(
            SwitchSnapshot::from_bytes(&snap[..cut]).is_err(),
            "prefix of length {cut} decoded as a whole snapshot"
        );
    }
    for cut in 0..delta.len() {
        assert!(
            SnapshotDelta::from_bytes(&delta[..cut]).is_err(),
            "delta prefix of length {cut} decoded whole"
        );
    }
}

#[test]
fn snapshot_decode_survives_bit_flips_and_inflation() {
    let (snap, delta) = fuzz_corpus();
    let mut rng = Pcg32::new(0xFA11);
    for trial in 0..400 {
        let base = if trial % 2 == 0 { &snap } else { &delta };
        let mut buf = base.clone();
        for _ in 0..1 + rng.gen_range_u64(8) {
            let bit = rng.gen_range_u64(buf.len() as u64 * 8) as usize;
            buf[bit / 8] ^= 1 << (bit % 8);
        }
        // Must not panic; Ok is legal when the flip lands in payload
        // bytes the structure does not constrain.
        if trial % 2 == 0 {
            let _ = SwitchSnapshot::from_bytes(&buf);
        } else {
            let _ = SnapshotDelta::from_bytes(&buf);
        }
        // Length inflation: trailing junk must be rejected, with or
        // without the flips.
        let mut inflated = base.clone();
        for _ in 0..1 + rng.gen_range_u64(64) {
            inflated.push(rng.gen_range_u64(256) as u8);
        }
        if trial % 2 == 0 {
            assert!(SwitchSnapshot::from_bytes(&inflated).is_err(), "trailing junk");
        } else {
            assert!(SnapshotDelta::from_bytes(&inflated).is_err(), "trailing junk");
        }
    }
}

#[test]
fn restore_rejects_a_snapshot_for_a_differently_configured_switch() {
    let tree = TreeId(1);
    let mut sw = configured(2, Parallelism::Serial, 1);
    let ss = scalar_streams(2, 200, 0xE1);
    let mut sink = IngestSink::new();
    for (c, s) in ss.iter().enumerate() {
        for p in &stamped(tree, s, c as u16) {
            sw.ingest_reliable_one(tree, p, &mut sink);
        }
    }
    let snap = sw.snapshot_tree(tree).expect("snapshot");
    // A standby with different geometry must refuse, not corrupt.
    let mut tiny = SwitchAggSwitch::new(SwitchConfig {
        parallelism: Parallelism::Serial,
        ..SwitchConfig::scaled(4 << 10, Some(64 << 10))
    });
    tiny.configure_vector(
        &[TreeConfig {
            tree,
            children: 2,
            parent_port: 0,
            op: AggOp::Sum,
        }],
        1,
    );
    assert!(
        tiny.restore_tree(&snap).is_err(),
        "geometry mismatch must be a typed error"
    );
}
