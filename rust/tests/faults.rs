//! Fault-injection co-simulation — integration contracts:
//!
//! * **Zero-fault transparency** — a chaos session with an empty
//!   `FaultPlan` is byte-identical to the plain transport session it
//!   wraps: same reducer stream, same ingress/egress/dedup stats, same
//!   JCT, same FIFO peak, zero faulted drops.  Scalar and W-lane
//!   vector (W ∈ {1, 8}), serial and sharded engines.  The fault
//!   machinery must cost *nothing* when no fault fires.
//! * **Crash recovery is exact** — a mid-job switch crash + restart
//!   (all FPE/BPE/dedup state lost) replays under a bumped epoch and
//!   lands on the *byte-identical* aggregate of the fault-free run.
//! * **Failover is exact over declared membership** — an unrecovered
//!   switch death completes via direct-to-reducer software merge with
//!   the same totals.
//! * **Quorum policy is typed** — a dead mapper under `All` quorum is
//!   a `ChaosError::QuorumUnreachable`, not a hang or a wrong answer;
//!   under `K-of-N` it is a re-planned membership.

use std::collections::HashMap;
use switchagg::framework::chaos::{
    run_chaos_scalar, run_chaos_vector, ChaosConfig, ChaosError, EotQuorum,
};
use switchagg::framework::transport::{run_transport_scalar, run_transport_vector};
use switchagg::framework::Reducer;
use switchagg::net::FaultPlan;
use switchagg::protocol::{
    AggOp, AggregationPacket, Key, KvPair, RelHeader, TreeConfig, TreeId, Value, VectorBatch,
};
use switchagg::switch::{
    IngestSink, Parallelism, QuotaRequest, SwitchAggSwitch, SwitchConfig,
};
use switchagg::util::rng::Pcg32;

fn switch_cfg(par: Parallelism) -> SwitchConfig {
    SwitchConfig {
        parallelism: par,
        ..SwitchConfig::scaled(16 << 10, Some(256 << 10))
    }
}

fn scalar_streams(children: usize, n: usize, seed: u64) -> Vec<Vec<KvPair>> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0x77);
            (0..n)
                .map(|_| {
                    let id = child.gen_range_u64(400);
                    KvPair::new(
                        Key::from_id(id, 16 + (id % 49) as usize),
                        child.gen_range_u64(200) as i64 - 100,
                    )
                })
                .collect()
        })
        .collect()
}

fn vector_streams(children: usize, n: usize, lanes: usize, seed: u64) -> Vec<VectorBatch> {
    let mut rng = Pcg32::new(seed);
    (0..children)
        .map(|_| {
            let mut child = rng.fork(0x88);
            let mut b = VectorBatch::new(lanes);
            let mut vals: Vec<Value> = vec![0; lanes];
            for _ in 0..n {
                let id = child.gen_range_u64(300);
                for (l, v) in vals.iter_mut().enumerate() {
                    *v = (id % 11) as i64 + l as i64 - 5;
                }
                b.push(Key::from_id(id, 16 + (id % 49) as usize), &vals);
            }
            b
        })
        .collect()
}

fn merged(pairs: &[KvPair]) -> HashMap<Key, Value> {
    Reducer::merge_software(&[pairs.to_vec()], AggOp::Sum).table
}

fn merged_streams(streams: &[Vec<KvPair>]) -> HashMap<Key, Value> {
    Reducer::merge_software(streams, AggOp::Sum).table
}

/// Manually-configured transport switch mirroring the session the
/// chaos runner launches through its controller (first launch ⇒
/// `TreeId(1)`).
fn transport_switch(children: u16, par: Parallelism, lanes: usize) -> SwitchAggSwitch {
    let mut sw = SwitchAggSwitch::new(switch_cfg(par));
    sw.configure_vector(
        &[TreeConfig {
            tree: TreeId(1),
            children,
            parent_port: 0,
            op: AggOp::Sum,
        }],
        lanes,
    );
    sw
}

// --- Zero-fault transparency -----------------------------------------

#[test]
fn empty_fault_plan_is_byte_identical_to_plain_transport_scalar() {
    let ss = scalar_streams(4, 900, 11);
    for par in [Parallelism::Serial, Parallelism::Sharded(2)] {
        let cfg = ChaosConfig::default();
        let chaos = run_chaos_scalar(&switch_cfg(par), AggOp::Sum, &ss, &cfg)
            .expect("fault-free chaos run");
        let mut sw = transport_switch(4, par, 1);
        let plain = run_transport_scalar(&mut sw, TreeId(1), AggOp::Sum, &ss, &cfg.transport);

        assert_eq!(chaos.received, plain.received, "{par:?}: reducer stream");
        assert_eq!(chaos.ingress, plain.ingress, "{par:?}: ingress hop stats");
        assert_eq!(chaos.egress, plain.egress, "{par:?}: egress hop stats");
        assert_eq!(chaos.dedup, plain.dedup, "{par:?}: dedup counters");
        assert_eq!(chaos.jct_s, plain.jct_s, "{par:?}: bit-identical JCT");
        assert_eq!(chaos.fifo_peak, plain.fifo_peak, "{par:?}");
        assert_eq!(chaos.faulted_drops, 0, "{par:?}");
        assert_eq!(chaos.final_epoch, 0);
        assert_eq!(chaos.restarts, 0);
        assert_eq!(chaos.replayed_packets, 0);
        assert!(!chaos.failed_over);
        assert_eq!(chaos.in_network, vec![0, 1, 2, 3]);
        assert!(chaos.software.is_empty() && chaos.excluded.is_empty());
    }
}

#[test]
fn empty_fault_plan_is_byte_identical_to_plain_transport_vector() {
    for lanes in [1usize, 8] {
        let ss = vector_streams(3, 500, lanes, 23);
        let cfg = ChaosConfig::default();
        let chaos = run_chaos_vector(
            &switch_cfg(Parallelism::Serial),
            AggOp::Sum,
            &ss,
            &cfg,
        )
        .expect("fault-free chaos run");
        let mut sw = transport_switch(3, Parallelism::Serial, lanes);
        let plain = run_transport_vector(&mut sw, TreeId(1), AggOp::Sum, &ss, &cfg.transport);

        assert_eq!(chaos.received, plain.received, "W={lanes}: reducer batch");
        assert_eq!(chaos.ingress, plain.ingress, "W={lanes}");
        assert_eq!(chaos.egress, plain.egress, "W={lanes}");
        assert_eq!(chaos.dedup, plain.dedup, "W={lanes}");
        assert_eq!(chaos.jct_s, plain.jct_s, "W={lanes}");
        assert_eq!(chaos.fifo_peak, plain.fifo_peak, "W={lanes}");
        assert_eq!(chaos.faulted_drops, 0, "W={lanes}");
        assert_eq!(chaos.restarts, 0);
    }
}

// --- Crash recovery --------------------------------------------------

#[test]
fn switch_crash_and_restart_recovers_the_exact_scalar_aggregate() {
    let ss = scalar_streams(4, 900, 31);
    let scfg = switch_cfg(Parallelism::Serial);
    let base = run_chaos_scalar(&scfg, AggOp::Sum, &ss, &ChaosConfig::default())
        .expect("baseline");
    let cfg = ChaosConfig {
        plan: FaultPlan::none().with_switch_crash(base.jct_s * 0.3, Some(base.jct_s * 0.6)),
        ..ChaosConfig::default()
    };
    let run = run_chaos_scalar(&scfg, AggOp::Sum, &ss, &cfg).expect("recovered run");
    assert_eq!(run.restarts, 1);
    assert_eq!(run.final_epoch, 1, "restart bumps the job epoch");
    assert!(run.faulted_drops > 0, "the outage must actually bite");
    assert!(run.replayed_packets > 0, "recovery replays from seq 1");
    assert_eq!(
        run.received, base.received,
        "epoch-fenced recovery must reproduce the fault-free aggregate byte-for-byte"
    );
    assert_eq!(merged(&run.received), merged_streams(&ss));
    assert!(run.jct_s > base.jct_s, "a mid-job outage cannot be free");
}

#[test]
fn switch_crash_and_restart_recovers_the_exact_vector_aggregate() {
    let lanes = 8;
    let ss = vector_streams(3, 500, lanes, 37);
    let scfg = switch_cfg(Parallelism::Serial);
    let base = run_chaos_vector(&scfg, AggOp::Sum, &ss, &ChaosConfig::default())
        .expect("baseline");
    let cfg = ChaosConfig {
        plan: FaultPlan::none().with_switch_crash(base.jct_s * 0.3, Some(base.jct_s * 0.6)),
        ..ChaosConfig::default()
    };
    let run = run_chaos_vector(&scfg, AggOp::Sum, &ss, &cfg).expect("recovered run");
    assert_eq!(run.restarts, 1);
    assert_eq!(run.final_epoch, 1);
    assert!(run.faulted_drops > 0);
    assert_eq!(run.received, base.received, "W={lanes} vector recovery");
}

#[test]
fn crash_recovery_is_engine_invariant() {
    let ss = scalar_streams(4, 900, 43);
    let serial_cfg = switch_cfg(Parallelism::Serial);
    let base = run_chaos_scalar(&serial_cfg, AggOp::Sum, &ss, &ChaosConfig::default())
        .expect("baseline");
    let cfg = ChaosConfig {
        plan: FaultPlan::none().with_switch_crash(base.jct_s * 0.4, Some(base.jct_s * 0.7)),
        ..ChaosConfig::default()
    };
    let a = run_chaos_scalar(&serial_cfg, AggOp::Sum, &ss, &cfg).expect("serial");
    let b = run_chaos_scalar(&switch_cfg(Parallelism::Sharded(2)), AggOp::Sum, &ss, &cfg)
        .expect("sharded");
    assert_eq!(a.received, b.received);
    assert_eq!(a.ingress, b.ingress);
    assert_eq!(a.faulted_drops, b.faulted_drops);
    assert_eq!(a.jct_s, b.jct_s);
}

// --- Failover & quorum ----------------------------------------------

#[test]
fn unrecovered_switch_death_fails_over_with_exact_totals() {
    let ss = scalar_streams(4, 900, 53);
    let scfg = switch_cfg(Parallelism::Serial);
    let base = run_chaos_scalar(&scfg, AggOp::Sum, &ss, &ChaosConfig::default())
        .expect("baseline");
    let cfg = ChaosConfig {
        plan: FaultPlan::none().with_switch_crash(base.jct_s * 0.3, None),
        max_retries: Some(6),
        ..ChaosConfig::default()
    };
    let run = run_chaos_scalar(&scfg, AggOp::Sum, &ss, &cfg).expect("failover run");
    assert!(run.failed_over);
    assert!(run.in_network.is_empty());
    assert_eq!(run.software, vec![0, 1, 2, 3], "all children merged in software");
    assert_eq!(
        merged(&run.received),
        merged_streams(&ss),
        "software failover must preserve the declared-membership totals"
    );
}

#[test]
fn dead_mapper_under_all_quorum_is_a_typed_error() {
    let ss = scalar_streams(4, 900, 61);
    let scfg = switch_cfg(Parallelism::Serial);
    let base = run_chaos_scalar(&scfg, AggOp::Sum, &ss, &ChaosConfig::default())
        .expect("baseline");
    let cfg = ChaosConfig {
        plan: FaultPlan::none().with_mapper_crash(1, base.jct_s * 0.3),
        ..ChaosConfig::default()
    };
    match run_chaos_scalar(&scfg, AggOp::Sum, &ss, &cfg) {
        Err(ChaosError::QuorumUnreachable { have, need }) => {
            assert_eq!(need, 4, "All quorum requires every launched child");
            assert!(have < need);
        }
        other => panic!("expected QuorumUnreachable, got {other:?}"),
    }
}

#[test]
fn dead_mapper_under_k_of_n_quorum_is_replanned_out_exactly() {
    let ss = scalar_streams(4, 900, 71);
    let scfg = switch_cfg(Parallelism::Serial);
    let base = run_chaos_scalar(&scfg, AggOp::Sum, &ss, &ChaosConfig::default())
        .expect("baseline");
    let cfg = ChaosConfig {
        plan: FaultPlan::none().with_mapper_crash(2, base.jct_s * 0.2),
        quorum: EotQuorum::KofN(3),
        quorum_deadline_s: Some(base.jct_s * 2.0),
        ..ChaosConfig::default()
    };
    let run = run_chaos_scalar(&scfg, AggOp::Sum, &ss, &cfg).expect("quorum run");
    assert_eq!(run.excluded, vec![2]);
    assert_eq!(run.in_network, vec![0, 1, 3]);
    let declared: Vec<Vec<KvPair>> = [0usize, 1, 3].iter().map(|&c| ss[c].clone()).collect();
    assert_eq!(
        merged(&run.received),
        merged_streams(&declared),
        "k-of-n totals must match the *declared* membership exactly"
    );
}

// --- Chaos × tenancy -------------------------------------------------

/// Stamp a pre-packed run with rel headers for `(child, epoch)`.
fn stamped(tree: TreeId, stream: &[KvPair], child: u16, epoch: u16) -> Vec<AggregationPacket> {
    let mut v = AggregationPacket::pack_stream(tree, AggOp::Sum, stream, true);
    for (i, p) in v.iter_mut().enumerate() {
        p.rel = Some(RelHeader {
            child,
            epoch,
            seq: i as u32 + 1,
        });
    }
    v
}

fn tenant_quota(cfg: &SwitchConfig, n: usize) -> QuotaRequest {
    QuotaRequest {
        fpe_bytes: (cfg.fpe_total_mem / n as u64).max(cfg.min_fpe_share(1)),
        bpe_bytes: cfg.bpe_mem.unwrap_or(0) / n as u64,
    }
}

/// A switch crash mid-way through a *multi-tenant* run: every
/// surviving tenant is re-admitted under a bumped epoch, pre-crash
/// stragglers are fenced (stale-epoch drops, not double counting), and
/// each survivor's replayed job lands on the byte-identical output of
/// its fault-free run.  A tenant that departs during the outage is NOT
/// re-admitted: its straggler is a counted unconfigured drop, never a
/// panic.
#[test]
fn multi_tenant_crash_recovery_fences_every_surviving_tenant() {
    let scfg = switch_cfg(Parallelism::Serial);
    let q = tenant_quota(&scfg, 4);
    let trees = [TreeId(1), TreeId(2), TreeId(3)];
    let streams: Vec<Vec<Vec<KvPair>>> = (0..trees.len())
        .map(|t| scalar_streams(2, 400, 0x90 + t as u64))
        .collect();
    let admit_all = |sw: &mut SwitchAggSwitch| {
        for (t, &tree) in trees.iter().enumerate() {
            sw.admit_tree(
                TreeConfig {
                    tree,
                    children: 2,
                    parent_port: 0,
                    op: AggOp::Sum,
                },
                q,
                1,
            )
            .unwrap_or_else(|e| panic!("tenant {t}: {e}"));
        }
    };
    let run_tenant = |sw: &mut SwitchAggSwitch, tree: TreeId, ss: &[Vec<KvPair>], epoch: u16| {
        let mut sink = IngestSink::new();
        let pkts: Vec<Vec<AggregationPacket>> = ss
            .iter()
            .enumerate()
            .map(|(c, s)| stamped(tree, s, c as u16, epoch))
            .collect();
        let longest = pkts.iter().map(|v| v.len()).max().unwrap_or(0);
        for i in 0..longest {
            for child in &pkts {
                if let Some(p) = child.get(i) {
                    sw.ingest_reliable_one(tree, p, &mut sink);
                }
            }
        }
        assert_eq!(sink.flushes, 1);
        sw.finalize(tree);
        sink
    };

    // Fault-free baseline: each tenant's exact emitted streams.
    let mut base_sw = SwitchAggSwitch::new(scfg.clone());
    admit_all(&mut base_sw);
    let baseline: Vec<IngestSink> = trees
        .iter()
        .enumerate()
        .map(|(t, &tree)| run_tenant(&mut base_sw, tree, &streams[t], 0))
        .collect();

    // Crash run: every tenant half-ingested when the switch dies.
    let mut sw = SwitchAggSwitch::new(scfg);
    admit_all(&mut sw);
    let mut lost = IngestSink::new();
    for (t, &tree) in trees.iter().enumerate() {
        let pkts = stamped(tree, &streams[t][0], 0, 0);
        for p in &pkts[..pkts.len() / 2] {
            sw.ingest_reliable_one(tree, p, &mut lost);
        }
    }
    sw.crash();

    // Recovery: tenants 1 and 2 survive (re-admitted, epoch bumped);
    // tenant 3 departed during the outage and is not re-admitted.
    for (t, &tree) in trees.iter().enumerate().take(2) {
        sw.admit_tree(
            TreeConfig {
                tree,
                children: 2,
                parent_port: 0,
                op: AggOp::Sum,
            },
            q,
            1,
        )
        .unwrap_or_else(|e| panic!("re-admit {t}: {e}"));
        sw.begin_epoch(tree, 1);
    }

    // Pre-crash stragglers arrive for everyone: fenced for survivors
    // (stale epoch), a counted drop for the departed tenant.
    let mut straggler_sink = IngestSink::new();
    for (t, &tree) in trees.iter().enumerate() {
        let pkts = stamped(tree, &streams[t][0], 0, 0);
        sw.ingest_reliable_one(tree, &pkts[0], &mut straggler_sink);
    }
    assert!(straggler_sink.forwarded.is_empty() && straggler_sink.flushed.is_empty());
    for &tree in &trees[..2] {
        assert_eq!(
            sw.dedup_stats(tree).stale_epoch_drops,
            1,
            "{tree}: pre-crash straggler must be epoch-fenced"
        );
    }
    assert_eq!(
        sw.unconfigured_drops(trees[2]),
        1,
        "the departed tenant's straggler is a counted drop, not a panic"
    );

    // Replay from seq 1 under the new epoch: byte-identical outputs.
    for (t, &tree) in trees.iter().enumerate().take(2) {
        let sink = run_tenant(&mut sw, tree, &streams[t], 1);
        assert_eq!(
            sink.forwarded, baseline[t].forwarded,
            "{tree}: replayed stream-phase output"
        );
        assert_eq!(
            sink.flushed, baseline[t].flushed,
            "{tree}: replayed flush output"
        );
        assert_eq!(
            merged(&sink.flushed),
            merged_streams(&streams[t]),
            "{tree}: recovered totals"
        );
    }
}
