//! Property-based tests (via `util::miniprop`) over the system's core
//! invariants: conservation laws, protocol round trips, model bounds,
//! and the equivalence between the ideal node and the real data plane.

use std::collections::{BTreeMap, HashMap};
use switchagg::analysis::models::{eq3_reduction_ratio, eq3_upper_bound};
use switchagg::analysis::theorems::IdealNode;
use switchagg::protocol::{
    AggOp, AggregationPacket, Key, KvPair, Packet, TreeConfig, TreeId,
};
use switchagg::switch::hash_table::{HashTable, Probe, VALUE_BYTES};
use switchagg::switch::scheduler::{SchedPolicy, Scheduler};
use switchagg::switch::{EvictionPolicy, SwitchAggSwitch, SwitchConfig};
use switchagg::util::miniprop::prop;
use switchagg::util::rng::Pcg32;

fn random_pairs(rng: &mut Pcg32, n: usize, variety: u64) -> Vec<KvPair> {
    (0..n)
        .map(|_| {
            let id = rng.gen_range_u64(variety);
            let len = 8 + (rng.gen_range_u64(57) as usize);
            KvPair::new(Key::from_id(id, len), rng.gen_range_u64(1000) as i64 - 500)
        })
        .collect()
}

#[test]
fn prop_packet_encode_decode_round_trip() {
    prop("packet round trip", 200, |rng| {
        let n = rng.gen_range_usize(40);
        let pairs = random_pairs(rng, n, 1 << 20);
        let pkt = Packet::Aggregation(AggregationPacket {
            tree: TreeId(rng.next_u32()),
            op: AggOp::ALL[rng.gen_range_usize(3)],
            eot: rng.gen_bool(0.5),
            rel: rng.gen_bool(0.5).then(|| switchagg::protocol::RelHeader {
                child: rng.gen_range_u64(64) as u16,
                epoch: rng.gen_range_u64(8) as u16,
                seq: rng.next_u32(),
            }),
            pairs,
        });
        let decoded = Packet::decode(&pkt.encode()).map_err(|e| e.to_string())?;
        if decoded != pkt {
            return Err("decode != original".into());
        }
        Ok(())
    });
}

#[test]
fn prop_pack_stream_preserves_order_and_content() {
    prop("pack_stream preserves content", 100, |rng| {
        let n = rng.gen_range_usize(3000);
        let pairs = random_pairs(rng, n, 1 << 16);
        let pkts = AggregationPacket::pack_stream(TreeId(1), AggOp::Sum, &pairs, true);
        let flat: Vec<KvPair> = pkts.iter().flat_map(|p| p.pairs.clone()).collect();
        if flat != pairs {
            return Err(format!("{} pairs -> {} after packing", pairs.len(), flat.len()));
        }
        if !pkts.last().map(|p| p.eot).unwrap_or(false) {
            return Err("missing EoT".into());
        }
        Ok(())
    });
}

#[test]
fn prop_switch_conserves_sum_for_any_config() {
    prop("switch conserves SUM", 30, |rng| {
        let fpe = 4096 << rng.gen_range_usize(6); // 4K..128K
        let bpe = if rng.gen_bool(0.5) {
            Some(1u64 << (16 + rng.gen_range_usize(6)))
        } else {
            None
        };
        let eviction = if rng.gen_bool(0.5) {
            EvictionPolicy::EvictOld
        } else {
            EvictionPolicy::ForwardNew
        };
        let cfg = SwitchConfig {
            eviction,
            ..SwitchConfig::scaled(fpe as u64, bpe)
        };
        let mut sw = SwitchAggSwitch::new(cfg);
        sw.configure(&[TreeConfig {
            tree: TreeId(1),
            children: 1,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        let n = 2000 + rng.gen_range_usize(3000);
        let pairs = random_pairs(rng, n, 1 << 10);
        let want: i64 = pairs.iter().map(|p| p.value).sum();
        let out = sw.ingest_stream(TreeId(1), AggOp::Sum, &pairs);
        let got: i64 = out.iter().map(|p| p.value).sum();
        if got != want {
            return Err(format!("sum {got} != {want} (fpe={fpe} bpe={bpe:?})"));
        }
        Ok(())
    });
}

#[test]
fn prop_switch_result_equals_hashmap_truth() {
    prop("switch equals software truth", 20, |rng| {
        let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(16 << 10, Some(256 << 10)));
        sw.configure(&[TreeConfig {
            tree: TreeId(1),
            children: 1,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        let pairs = random_pairs(rng, 4000, 700);
        let out = sw.ingest_stream(TreeId(1), AggOp::Sum, &pairs);
        let mut truth: HashMap<Key, i64> = HashMap::new();
        for p in &pairs {
            *truth.entry(p.key).or_insert(0) += p.value;
        }
        let mut got: HashMap<Key, i64> = HashMap::new();
        for p in &out {
            *got.entry(p.key).or_insert(0) += p.value;
        }
        if got != truth {
            return Err("re-aggregated output differs from truth".into());
        }
        Ok(())
    });
}

#[test]
fn prop_output_never_exceeds_input() {
    prop("no amplification", 30, |rng| {
        let mut sw = SwitchAggSwitch::new(SwitchConfig::scaled(8 << 10, None));
        sw.configure(&[TreeConfig {
            tree: TreeId(1),
            children: 1,
            parent_port: 0,
            op: AggOp::Sum,
        }]);
        let n = 1000 + rng.gen_range_usize(4000);
        let pairs = random_pairs(rng, n, 1 << 14);
        let out = sw.ingest_stream(TreeId(1), AggOp::Sum, &pairs);
        if out.len() > pairs.len() {
            return Err(format!("{} out > {} in", out.len(), pairs.len()));
        }
        let s = sw.stats(TreeId(1)).unwrap();
        if s.reduction_ratio() < -0.12 {
            // Output bytes may slightly exceed input on incompressible
            // streams (packet-header effects) but never by much.
            return Err(format!("reduction {}", s.reduction_ratio()));
        }
        Ok(())
    });
}

#[test]
fn prop_eq3_matches_ideal_node_on_even_data() {
    // Eq. 3 is derived for data *evenly distributed* among the N keys
    // (each key appears exactly M/N times).  Build exactly that, in a
    // random order, and the ideal node must track the closed form.
    prop("Eq.3 matches the ideal node (even data)", 40, |rng| {
        let variety = 100 + rng.gen_range_u64(5_000);
        let reps = 2 + rng.gen_range_usize(6);
        let cap = 50 + rng.gen_range_usize(2_000);
        let mut pairs: Vec<KvPair> = (0..variety)
            .flat_map(|id| {
                std::iter::repeat(KvPair::new(Key::from_id(id, 16), 1)).take(reps)
            })
            .collect();
        rng.shuffle(&mut pairs);
        let m = pairs.len() as u64;
        let (_, r_sim) = IdealNode::run(cap, &pairs, AggOp::Sum);
        let r_model = eq3_reduction_ratio(m, variety, cap as u64);
        if (r_sim - r_model).abs() > 0.05 {
            return Err(format!(
                "sim {r_sim:.4} vs model {r_model:.4} (m={m} variety={variety} cap={cap} reps={reps})"
            ));
        }
        if variety > cap as u64 && r_sim > eq3_upper_bound(variety, cap as u64) + 0.05 {
            return Err(format!("sim {r_sim} exceeds C/N bound"));
        }
        Ok(())
    });
}

#[test]
fn prop_random_draws_beat_eq3_via_size_bias() {
    // Characterization: with *randomly drawn* keys (not exactly even),
    // early-captured keys are size-biased towards frequent ones, so
    // the ideal node does at least as well as Eq. 3 predicts.
    prop("random draws >= Eq.3", 20, |rng| {
        let variety = 500 + rng.gen_range_u64(4_000);
        let cap = 100 + rng.gen_range_usize(1_500);
        let n = 8_000 + rng.gen_range_usize(12_000);
        let pairs: Vec<KvPair> = (0..n)
            .map(|_| KvPair::new(Key::from_id(rng.gen_range_u64(variety), 16), 1))
            .collect();
        let (_, r_sim) = IdealNode::run(cap, &pairs, AggOp::Sum);
        let r_model = eq3_reduction_ratio(n as u64, variety, cap as u64);
        if r_sim < r_model - 0.05 {
            return Err(format!("sim {r_sim:.4} below model {r_model:.4}"));
        }
        Ok(())
    });
}

#[test]
fn prop_soa_table_matches_reference_model() {
    // Differential test of the SoA/tag-filtered table core against a
    // BTreeMap reference model driven by the table's own probe
    // outcomes, across key widths 8–64 B, both eviction policies, and
    // random offer/evict/drain sequences: resident sets must be
    // identical and SUM must be conserved exactly
    // (inputs == residents + everything that ever left).
    prop("SoA table == reference model", 60, |rng| {
        let width = 8 * (1 + rng.gen_range_usize(8)); // 8..=64, /4
        let spb = 1 + rng.gen_range_usize(4); // 1..=4
        let bucket_count = 1 + rng.gen_range_usize(64);
        let mut t = HashTable::with_memory(
            (bucket_count * spb * (width + VALUE_BYTES)) as u64,
            width,
            spb,
        );
        let evict_old = rng.gen_bool(0.5);
        let variety = 1 + rng.gen_range_u64(512);
        let mut model: BTreeMap<Vec<u8>, i64> = BTreeMap::new();
        let mut input_sum = 0i64;
        let mut departed_sum = 0i64;
        let steps = 500 + rng.gen_range_usize(1500);
        for step in 0..steps {
            if rng.gen_bool(0.02) {
                // Drain: the table must empty into exactly the model.
                let drained = t.drain();
                let got: BTreeMap<Vec<u8>, i64> = drained
                    .iter()
                    .map(|(k, v)| (k.as_bytes().to_vec(), *v))
                    .collect();
                if got.len() != drained.len() {
                    return Err(format!("step {step}: duplicate keys in drain"));
                }
                if got != model {
                    return Err(format!(
                        "step {step}: drained set diverged ({} vs {} keys)",
                        got.len(),
                        model.len()
                    ));
                }
                departed_sum += drained.iter().map(|(_, v)| v).sum::<i64>();
                model.clear();
                if t.occupancy() != 0 {
                    return Err("occupancy nonzero after drain".into());
                }
                continue;
            }
            let klen = 8 + rng.gen_range_usize(width - 7); // 8..=width
            let key = Key::from_id(rng.gen_range_u64(variety), klen);
            let kb = key.as_bytes().to_vec();
            let v = rng.gen_range_u64(1000) as i64 - 500;
            input_sum += v;
            let hash = t.hash_of(&key);
            match t.offer_hashed(hash, key, v, AggOp::Sum, evict_old) {
                Probe::Aggregated => match model.get_mut(&kb) {
                    Some(mv) => *mv += v,
                    None => return Err(format!("step {step}: aggregated a non-resident key")),
                },
                Probe::Inserted => {
                    if model.insert(kb.clone(), v).is_some() {
                        return Err(format!("step {step}: inserted an already-resident key"));
                    }
                }
                Probe::Evicted(ek, ev, etag) => {
                    if etag != t.hash_of(&ek) {
                        return Err(format!("step {step}: evictee tag != its hash"));
                    }
                    departed_sum += ev;
                    if evict_old {
                        let ekb = ek.as_bytes().to_vec();
                        match model.remove(&ekb) {
                            Some(mv) if mv == ev => {}
                            other => {
                                return Err(format!(
                                    "step {step}: evicted ({ek:?},{ev}) but model had {other:?}"
                                ))
                            }
                        }
                        if model.insert(kb.clone(), v).is_some() {
                            return Err(format!("step {step}: newcomer was already resident"));
                        }
                    } else if ek != key || ev != v {
                        return Err(format!("step {step}: ForwardNew evicted a resident pair"));
                    }
                }
            }
            // Spot-check the read path (hash already in hand, as in the
            // BPE/verification paths).
            match (t.get_hashed(hash, &key), model.get(&kb)) {
                (Some(a), Some(&b)) if a == b => {}
                (None, None) => {}
                (got, want) => {
                    return Err(format!("step {step}: get_hashed {got:?} vs model {want:?}"))
                }
            }
        }
        // Final resident set and conservation.
        let resident: BTreeMap<Vec<u8>, i64> = t
            .iter()
            .map(|(k, v)| (k.as_bytes().to_vec(), v))
            .collect();
        if resident != model {
            return Err(format!(
                "final resident set diverged ({} vs {} keys, evict_old={evict_old})",
                resident.len(),
                model.len()
            ));
        }
        if t.occupancy() != model.len() {
            return Err("occupancy != model size".into());
        }
        let resident_sum: i64 = resident.values().sum();
        if input_sum != resident_sum + departed_sum {
            return Err(format!(
                "SUM not conserved: in={input_sum} resident={resident_sum} departed={departed_sum}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_agg_ops_idempotence_and_identity() {
    prop("op algebra", 300, |rng| {
        let op = AggOp::ALL[rng.gen_range_usize(3)];
        let a = rng.next_u32() as i64 - (1 << 31);
        let b = rng.next_u32() as i64 - (1 << 31);
        if op.combine(a, b) != op.combine(b, a) {
            return Err(format!("{op} not commutative for {a},{b}"));
        }
        if op.combine(a, op.identity()) != a {
            return Err(format!("{op} identity broken for {a}"));
        }
        if matches!(op, AggOp::Max | AggOp::Min) && op.combine(a, a) != a {
            return Err(format!("{op} not idempotent for {a}"));
        }
        Ok(())
    });
}

#[test]
fn prop_key_round_trip_and_hash_stability() {
    prop("key pack/hash", 300, |rng| {
        let len = 1 + rng.gen_range_usize(64);
        let id = rng.gen_range_u64(1u64 << (8 * len.min(7)) as u32);
        let key = Key::from_id(id, len);
        let width = len.div_ceil(8).max(1) * 8;
        let words = key.packed_words(width);
        if words.len() != width / 4 {
            return Err("packed width mismatch".into());
        }
        let h1 = switchagg::switch::hash::fnv1a_key(&key, width);
        let h2 = switchagg::switch::hash::fnv1a_words(&words);
        if h1 != h2 {
            return Err(format!("hash mismatch len={len}"));
        }
        Ok(())
    });
}

#[test]
fn prop_lqf_pick_matches_naive_argmax_oracle() {
    // The LongestQueueFirst tiebreak is encoded as `(d, n - i)` in the
    // scheduler; pin it against the definitional oracle — argmax depth,
    // ties broken by the lowest index — over random depth vectors and
    // several consecutive picks (the cursor must not perturb LQF).
    prop("LQF pick == argmax-lowest-index oracle", 150, |rng| {
        let n = 1 + rng.gen_range_usize(8);
        let mut s = Scheduler::new(n, SchedPolicy::LongestQueueFirst);
        let mut depths: Vec<usize> = (0..n).map(|_| rng.gen_range_usize(5)).collect();
        for round in 0..6 {
            let oracle = {
                let max = depths.iter().copied().max().unwrap_or(0);
                if max == 0 {
                    None
                } else {
                    depths.iter().position(|&d| d == max)
                }
            };
            let got = s.pick(&depths);
            if got != oracle {
                return Err(format!(
                    "round {round}: pick {got:?} != oracle {oracle:?} for {depths:?}"
                ));
            }
            if let Some(i) = got {
                depths[i] -= 1; // serve the granted queue and repeat
            } else {
                break;
            }
        }
        Ok(())
    });
}
