"""Layer-2 JAX compute graph: the aggregation steps SwitchAgg executes.

Each public function here is an AOT entry point: ``aot.py`` lowers it
once to HLO text and the Rust runtime (rust/src/runtime/engine.rs)
compiles + executes it on the PJRT CPU client.  Python never runs on the
request path.

Entry points:

  * ``aggregate_sum / aggregate_max / aggregate_min`` — f32 table merge
    (reducer final merge; XLA-accelerated BPE batch drain).
  * ``aggregate_sum_i32`` — integer SUM (WordCount counts).
  * ``hash_keys`` — FNV-1a-32 over packed key words (bit-exact with
    rust/src/switch/hash.rs).
  * ``hash_aggregate_sum`` — fused hash→bucket→aggregate: the full FPE
    datapath (hash unit + memory management + aggregation unit, Fig. 6)
    as one graph, so XLA fuses the three stages the way the FPGA
    pipelines them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import aggregate as agg_kernel
from .kernels import hash_fnv

# Canonical AOT shapes — keep in sync with rust/src/runtime/engine.rs and
# artifacts/manifest.json (written by aot.py).
TABLE_SIZE = agg_kernel.TABLE_SIZE  # 65536 slots
BATCH_SIZE = agg_kernel.BATCH_SIZE  # 1024 pairs per execute
KEY_WORDS = hash_fnv.KEY_WORDS  # 16 u32 words = 64 B max key


def aggregate_sum(table, idx, vals):
    """f32 segment-SUM of a batch into the slot table."""
    return (agg_kernel.scatter_aggregate(table, idx, vals, op="sum"),)


def aggregate_max(table, idx, vals):
    """f32 segment-MAX of a batch into the slot table."""
    return (agg_kernel.scatter_aggregate(table, idx, vals, op="max"),)


def aggregate_min(table, idx, vals):
    """f32 segment-MIN of a batch into the slot table."""
    return (agg_kernel.scatter_aggregate(table, idx, vals, op="min"),)


def aggregate_sum_i32(table, idx, vals):
    """i32 segment-SUM (WordCount counts are integers)."""
    return (agg_kernel.scatter_aggregate(table, idx, vals, op="sum"),)


def hash_keys(words):
    """FNV-1a-32 each packed key; returns u32[B]."""
    return (hash_fnv.fnv1a_hash(words),)


def hash_aggregate_sum(table, words, vals):
    """Fused FPE datapath: hash keys, map to buckets, segment-SUM.

    Bucket = hash mod TABLE_SIZE.  This is the *approximate* (hash-only)
    aggregation the switch data plane performs; exact-key residency is
    the Rust coordinator's job.  A zero key row (all words zero) is
    treated as a padding lane.
    """
    hashes = hash_fnv.fnv1a_hash(words)
    idx = (hashes % jnp.uint32(table.shape[0])).astype(jnp.int32)
    padding = jnp.all(words == 0, axis=1)
    idx = jnp.where(padding, -1, idx)
    return (agg_kernel.scatter_aggregate(table, idx, vals, op="sum"),)


def _scatter_entry(op):
    """CPU-fast variant: native XLA scatter instead of the Pallas
    table-tiled kernel.

    The Pallas kernel is the *TPU* design (one-hot matmuls feed the
    MXU, DESIGN.md §Hardware-Adaptation); under interpret=True on the
    CPU PJRT client its lowering is a while-loop nest doing O(B·T)
    work per batch.  XLA's scatter lowers to O(B) updates on CPU, so
    the Rust engine prefers these `*_xla` twins on the request path
    (SWITCHAGG_KERNEL=pallas forces the Pallas artifacts; tests assert
    both produce identical tables).
    """

    def fn(table, idx, vals):
        from .kernels.ref import ref_scatter_aggregate

        return (ref_scatter_aggregate(table, idx, vals, op=op),)

    fn.__name__ = f"aggregate_{op}_scatter"
    return fn


def entry_points():
    """name -> (fn, arg ShapeDtypeStructs). Consumed by aot.py."""
    f32 = jnp.float32
    i32 = jnp.int32
    u32 = jnp.uint32
    table_f = jax.ShapeDtypeStruct((TABLE_SIZE,), f32)
    table_i = jax.ShapeDtypeStruct((TABLE_SIZE,), i32)
    idx = jax.ShapeDtypeStruct((BATCH_SIZE,), i32)
    vals_f = jax.ShapeDtypeStruct((BATCH_SIZE,), f32)
    vals_i = jax.ShapeDtypeStruct((BATCH_SIZE,), i32)
    words = jax.ShapeDtypeStruct((BATCH_SIZE, KEY_WORDS), u32)
    return {
        # Pallas table-tiled kernels (the paper-mapped TPU design).
        "agg_sum_f32": (aggregate_sum, (table_f, idx, vals_f)),
        "agg_max_f32": (aggregate_max, (table_f, idx, vals_f)),
        "agg_min_f32": (aggregate_min, (table_f, idx, vals_f)),
        "agg_sum_i32": (aggregate_sum_i32, (table_i, idx, vals_i)),
        "hash_fnv": (hash_keys, (words,)),
        "hash_agg_sum_f32": (hash_aggregate_sum, (table_f, words, vals_f)),
        # CPU-fast scatter twins (request-path default on PJRT CPU).
        "agg_sum_f32_xla": (_scatter_entry("sum"), (table_f, idx, vals_f)),
        "agg_max_f32_xla": (_scatter_entry("max"), (table_f, idx, vals_f)),
        "agg_min_f32_xla": (_scatter_entry("min"), (table_f, idx, vals_f)),
        "agg_sum_i32_xla": (_scatter_entry("sum"), (table_i, idx, vals_i)),
    }


@functools.lru_cache(maxsize=None)
def lowered(name: str):
    """Lower one entry point (cached); returns the jax Lowered object."""
    fn, specs = entry_points()[name]
    return jax.jit(fn).lower(*specs)
