"""Pallas scatter-aggregate kernel: the SwitchAgg aggregation unit.

The paper's processing engine performs, per key-value pair, a hash-table
lookup followed by ``slot.value = op(slot.value, value)`` (SUM/MAX/MIN,
§4.2.4).  On the FPGA this is a 1-cycle BRAM read-modify-write; a TPU has
no per-slot scratchpad RMW, so the kernel re-expresses a *batch* of B
pairs as dense, streaming compute over table tiles (DESIGN.md
§Hardware-Adaptation):

  * grid = (T // TILE_T, B // TILE_B) — the table is tiled so each tile
    fits VMEM; batch chunks stream through while a tile is resident.
  * SUM uses ``vals @ one_hot(idx)`` so the MXU systolic array performs
    the segment reduction (the TPU analogue of "aggregate without
    pipeline stall").
  * MAX/MIN use a masked elementwise reduce over the batch chunk.
  * ``idx < 0`` marks padding lanes (Rust pads partial batches); they
    contribute the op identity.

Each table element is read and written exactly once per batch — the
kernel is HBM-bandwidth-bound, which is its roofline.

Correctness oracle: :mod:`python.compile.kernels.ref` (pure jnp), checked
by ``python/tests/test_kernel.py`` under hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default AOT shapes (must match rust/src/runtime/engine.rs and the
# artifact manifest written by aot.py).
TABLE_SIZE = 65536
BATCH_SIZE = 1024
# Tile sizes chosen so the one-hot sub-block (TILE_B x TILE_T f32) peaks
# at 256*2048*4 = 2 MiB of VMEM, within a 16 MiB budget together with the
# resident table tile, batch chunk, and double-buffered next tile.
TILE_T = 2048
TILE_B = 256

OPS = ("sum", "max", "min")

#: op -> identity element (what padding lanes contribute, and what an
#: empty table slot holds).  Mirrors rust/src/switch/aggregate.rs.
IDENTITY = {"sum": 0.0, "max": -jnp.inf, "min": jnp.inf}


def _agg_kernel(table_ref, idx_ref, vals_ref, o_ref, *, op: str, tile_t: int):
    """One (table-tile, batch-chunk) grid step.

    Grid dim 0 walks table tiles (parallel); grid dim 1 walks batch
    chunks (sequential accumulation into ``o_ref``).
    """
    t = pl.program_id(0)
    b = pl.program_id(1)

    # First batch chunk for this tile: seed the output with the current
    # table contents.
    @pl.when(b == 0)
    def _seed():
        o_ref[...] = table_ref[...]

    idx = idx_ref[...]  # i32[TILE_B], global slot ids (or <0 = padding)
    vals = vals_ref[...]  # f32[TILE_B]

    # Global ids covered by this table tile.
    base = t * tile_t
    tile_ids = base + jax.lax.broadcasted_iota(jnp.int32, (tile_t,), 0)

    # one_hot[b, t] — does batch lane b target tile position t?
    # Padding lanes (idx < 0) match nothing.
    hit = (idx[:, None] == tile_ids[None, :]) & (idx[:, None] >= 0)

    if op == "sum":
        # MXU path: vector-matrix product performs the segment sum.
        contrib = jnp.dot(
            vals,
            hit.astype(vals.dtype),
            preferred_element_type=vals.dtype,
        )
        o_ref[...] = o_ref[...] + contrib
    elif op == "max":
        masked = jnp.where(hit, vals[:, None], -jnp.inf)
        o_ref[...] = jnp.maximum(o_ref[...], jnp.max(masked, axis=0))
    elif op == "min":
        masked = jnp.where(hit, vals[:, None], jnp.inf)
        o_ref[...] = jnp.minimum(o_ref[...], jnp.min(masked, axis=0))
    else:  # pragma: no cover - guarded by OPS
        raise ValueError(f"unknown op {op!r}")


def _agg_kernel_i32(table_ref, idx_ref, vals_ref, o_ref, *, tile_t: int):
    """Integer SUM variant (word-count style aggregation).

    int32 matmul has no MXU path; use multiply+reduce which XLA
    vectorizes on CPU and the VPU handles on TPU.
    """
    t = pl.program_id(0)
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _seed():
        o_ref[...] = table_ref[...]

    idx = idx_ref[...]
    vals = vals_ref[...]
    base = t * tile_t
    tile_ids = base + jax.lax.broadcasted_iota(jnp.int32, (tile_t,), 0)
    hit = (idx[:, None] == tile_ids[None, :]) & (idx[:, None] >= 0)
    contrib = jnp.sum(jnp.where(hit, vals[:, None], 0), axis=0, dtype=jnp.int32)
    o_ref[...] = o_ref[...] + contrib


def _tile_sizes(table_size: int, batch_size: int) -> tuple[int, int]:
    tile_t = min(TILE_T, table_size)
    tile_b = min(TILE_B, batch_size)
    if table_size % tile_t or batch_size % tile_b:
        raise ValueError(
            f"table_size {table_size} / batch_size {batch_size} must be "
            f"divisible by tile sizes ({tile_t}, {tile_b})"
        )
    return tile_t, tile_b


@functools.partial(jax.jit, static_argnames=("op",))
def scatter_aggregate(table, idx, vals, *, op: str = "sum"):
    """Aggregate ``vals`` into ``table`` at positions ``idx``.

    Args:
      table: f32[T] or i32[T] current slot values (identity-initialized
        for empty slots).
      idx:   i32[B] target slot per batch lane; negative = padding lane.
      vals:  same dtype as table, [B].
      op:    "sum" | "max" | "min" ("max"/"min" are f32-only).

    Returns the updated table; every slot is touched exactly once.
    """
    if op not in OPS:
        raise ValueError(f"op must be one of {OPS}, got {op!r}")
    table_size, batch_size = table.shape[0], idx.shape[0]
    tile_t, tile_b = _tile_sizes(table_size, batch_size)

    if table.dtype == jnp.int32:
        if op != "sum":
            raise ValueError("int32 tables support only op='sum'")
        kernel = functools.partial(_agg_kernel_i32, tile_t=tile_t)
    else:
        kernel = functools.partial(_agg_kernel, op=op, tile_t=tile_t)

    grid = (table_size // tile_t, batch_size // tile_b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_t,), lambda t, b: (t,)),
            pl.BlockSpec((tile_b,), lambda t, b: (b,)),
            pl.BlockSpec((tile_b,), lambda t, b: (b,)),
        ],
        out_specs=pl.BlockSpec((tile_t,), lambda t, b: (t,)),
        out_shape=jax.ShapeDtypeStruct((table_size,), table.dtype),
        interpret=True,
    )(table, idx, vals)
