"""Layer-1 Pallas kernels for the SwitchAgg aggregation hot-spot.

`aggregate` — table-tiled scatter-aggregate (SUM/MAX/MIN) used by the
reducer merge and the XLA-accelerated BPE batch drain.
`hash_fnv`  — word-level FNV-1a-32 key hashing, bit-exact with the Rust
implementation in ``rust/src/switch/hash.rs``.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin the
Rust side uses cannot execute Mosaic custom-calls (see DESIGN.md
§Hardware-Adaptation for the TPU mapping).
"""
