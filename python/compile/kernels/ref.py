"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth for correctness: no Pallas, no tiling — just
XLA scatter ops and a plain python word loop.  The pytest suite asserts
the kernels match these bit-for-bit (hashes) / to float tolerance
(aggregation).
"""

from __future__ import annotations

import jax.numpy as jnp

from .aggregate import IDENTITY  # noqa: F401  (re-exported for tests)
from .hash_fnv import FNV_OFFSET, FNV_PRIME


def ref_scatter_aggregate(table, idx, vals, *, op: str = "sum"):
    """Reference scatter-aggregate using jnp indexed updates.

    Padding lanes (idx < 0) are dropped before scattering.
    """
    valid = idx >= 0
    # Route padding lanes to slot 0 with the op identity so shapes stay
    # static (jit-compatible); identity contributions are no-ops.
    safe_idx = jnp.where(valid, idx, 0)
    if op == "sum":
        safe_vals = jnp.where(valid, vals, jnp.zeros_like(vals))
        return table.at[safe_idx].add(safe_vals)
    if op == "max":
        safe_vals = jnp.where(valid, vals, -jnp.inf)
        return table.at[safe_idx].max(safe_vals)
    if op == "min":
        safe_vals = jnp.where(valid, vals, jnp.inf)
        return table.at[safe_idx].min(safe_vals)
    raise ValueError(f"unknown op {op!r}")


def ref_fnv1a_hash(words):
    """Reference word-level FNV-1a-32 over u32[B, W] rows."""
    words = words.astype(jnp.uint32)
    h = jnp.full((words.shape[0],), FNV_OFFSET, dtype=jnp.uint32)
    for i in range(words.shape[1]):
        h = (h ^ words[:, i]) * jnp.uint32(FNV_PRIME)
    return h


def fnv1a_hash_py(words_row) -> int:
    """Plain-python single-row oracle (for tiny hand-checked cases)."""
    h = FNV_OFFSET
    for w in words_row:
        h = ((h ^ int(w)) * FNV_PRIME) & 0xFFFFFFFF
    return h
