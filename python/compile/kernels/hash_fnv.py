"""Pallas FNV-1a-32 hash kernel over packed key words.

SwitchAgg's processing engines share one hash function that "accepts
different length inputs and gives a fixed length output" (§4.2.4).  We
define it word-level: keys are zero-padded to W 32-bit little-endian
words and

    h = 2166136261
    for each word w: h = (h XOR w) * 16777619   (mod 2^32)

``rust/src/switch/hash.rs::fnv1a_words`` implements the identical
function; ``rust/tests/integration_runtime.rs`` asserts bit-equality
across the language boundary through the AOT artifact.

The kernel is embarrassingly parallel over the batch; the word loop is a
``fori_loop`` so W stays a runtime-visible constant in the lowered HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FNV_OFFSET = 2166136261
FNV_PRIME = 16777619

# Default AOT shapes (see aot.py manifest): 64-byte max key = 16 words.
KEY_WORDS = 16
TILE_B = 256


def _hash_kernel(words_ref, o_ref, *, n_words: int):
    words = words_ref[...].astype(jnp.uint32)  # [TILE_B, W]
    h0 = jnp.full((words.shape[0],), FNV_OFFSET, dtype=jnp.uint32)

    def body(i, h):
        w = jax.lax.dynamic_slice_in_dim(words, i, 1, axis=1)[:, 0]
        return (h ^ w) * jnp.uint32(FNV_PRIME)

    o_ref[...] = jax.lax.fori_loop(0, n_words, body, h0)


@jax.jit
def fnv1a_hash(words):
    """Hash each row of ``words`` (u32[B, W]) to u32[B]."""
    batch, n_words = words.shape
    tile_b = min(TILE_B, batch)
    if batch % tile_b:
        raise ValueError(f"batch {batch} not divisible by tile {tile_b}")
    import functools

    return pl.pallas_call(
        functools.partial(_hash_kernel, n_words=n_words),
        grid=(batch // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, n_words), lambda b: (b, 0))],
        out_specs=pl.BlockSpec((tile_b,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.uint32),
        interpret=True,
    )(words)
