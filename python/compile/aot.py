"""AOT-lower the L2 entry points to HLO text artifacts for the Rust runtime.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and
round-trips cleanly — see /opt/xla-example/README.md.

Usage:  python -m compile.aot [--out-dir ../artifacts] [--only NAME]

Writes ``<name>.hlo.txt`` per entry point plus ``manifest.json``
describing shapes/dtypes, which rust/src/runtime/artifacts.rs validates
at load time.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered_fn) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True;
    the Rust side unwraps with ``to_tuple1``)."""
    mlir_mod = lowered_fn.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str, only: str | None = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {
        "format": "hlo-text",
        "table_size": model.TABLE_SIZE,
        "batch_size": model.BATCH_SIZE,
        "key_words": model.KEY_WORDS,
        "entries": {},
    }
    for name, (fn, specs) in model.entry_points().items():
        if only is not None and name != only:
            continue
        text = to_hlo_text(model.lowered(name))
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {"shape": list(s.shape), "dtype": s.dtype.name} for s in specs
            ],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    # TSV twin for the Rust loader (the offline crate set has no serde;
    # a line-oriented format keeps rust/src/runtime/artifacts.rs trivial).
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write(f"table_size\t{manifest['table_size']}\n")
        f.write(f"batch_size\t{manifest['batch_size']}\n")
        f.write(f"key_words\t{manifest['key_words']}\n")
        for name in sorted(manifest["entries"]):
            e = manifest["entries"][name]
            args = ";".join(
                f"{a['dtype']}:" + ",".join(str(d) for d in a["shape"])
                for a in e["args"]
            )
            f.write(f"entry\t{name}\t{e['file']}\t{args}\n")
    print(f"wrote {os.path.join(out_dir, 'manifest.json')} (+.tsv)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)  # legacy
    ap.add_argument("--only", default=None, help="build a single entry point")
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    build(out_dir or ".", args.only)


if __name__ == "__main__":
    main()
