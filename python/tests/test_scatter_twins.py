"""The *_xla scatter twins must be numerically identical to the Pallas
kernels — they are alternative lowerings of the same operation, chosen
by the Rust engine per target (DESIGN.md §Perf)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import aggregate


def _entry(name):
    fn, _ = model.entry_points()[name]
    return fn


@settings(deadline=None, max_examples=6)
@given(op=st.sampled_from(["sum", "max", "min"]), seed=st.integers(0, 2**31 - 1))
def test_pallas_and_scatter_twins_agree_f32(op, seed):
    rng = np.random.default_rng(seed)
    table = jnp.full((model.TABLE_SIZE,), aggregate.IDENTITY[op], jnp.float32)
    idx = jnp.asarray(
        rng.integers(-1, model.TABLE_SIZE, model.BATCH_SIZE), jnp.int32
    )
    vals = jnp.asarray(rng.normal(size=model.BATCH_SIZE), jnp.float32)
    (pallas_out,) = _entry(f"agg_{op}_f32")(table, idx, vals)
    (scatter_out,) = _entry(f"agg_{op}_f32_xla")(table, idx, vals)
    np.testing.assert_allclose(pallas_out, scatter_out, rtol=1e-5, atol=1e-5)


def test_i32_twins_agree_exactly():
    rng = np.random.default_rng(0)
    table = jnp.asarray(
        rng.integers(-50, 50, model.TABLE_SIZE).astype(np.int32)
    )
    idx = jnp.asarray(
        rng.integers(-1, model.TABLE_SIZE, model.BATCH_SIZE), jnp.int32
    )
    vals = jnp.asarray(
        rng.integers(-100, 100, model.BATCH_SIZE).astype(np.int32)
    )
    (a,) = _entry("agg_sum_i32")(table, idx, vals)
    (b,) = _entry("agg_sum_i32_xla")(table, idx, vals)
    np.testing.assert_array_equal(a, b)
