"""L2 model tests: entry points execute, shapes match, fusion semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def test_entry_points_cover_all_ops():
    eps = model.entry_points()
    assert set(eps) == {
        "agg_sum_f32",
        "agg_max_f32",
        "agg_min_f32",
        "agg_sum_i32",
        "hash_fnv",
        "hash_agg_sum_f32",
        # CPU-fast scatter twins (request-path default on PJRT CPU).
        "agg_sum_f32_xla",
        "agg_max_f32_xla",
        "agg_min_f32_xla",
        "agg_sum_i32_xla",
    }
    for name, (fn, specs) in eps.items():
        assert callable(fn), name
        assert all(isinstance(s, jax.ShapeDtypeStruct) for s in specs), name


def test_aggregate_entry_returns_tuple1():
    table = jnp.zeros((model.TABLE_SIZE,), jnp.float32)
    idx = jnp.full((model.BATCH_SIZE,), -1, jnp.int32)
    vals = jnp.zeros((model.BATCH_SIZE,), jnp.float32)
    out = model.aggregate_sum(table, idx, vals)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (model.TABLE_SIZE,)


def test_hash_aggregate_fused_equals_two_step():
    rng = np.random.default_rng(7)
    batch, words_n = model.BATCH_SIZE, model.KEY_WORDS
    words = rng.integers(1, 2**32, (batch, words_n), dtype=np.uint64).astype(
        np.uint32
    )
    words[::5] = 0  # padding lanes
    vals = rng.normal(size=batch).astype(np.float32)
    table = jnp.zeros((model.TABLE_SIZE,), jnp.float32)

    (fused,) = model.hash_aggregate_sum(table, jnp.asarray(words), jnp.asarray(vals))

    hashes = np.asarray(ref.ref_fnv1a_hash(jnp.asarray(words)))
    idx = (hashes % model.TABLE_SIZE).astype(np.int32)
    idx[(words == 0).all(axis=1)] = -1
    want = ref.ref_scatter_aggregate(
        table, jnp.asarray(idx), jnp.asarray(vals), op="sum"
    )
    np.testing.assert_allclose(fused, want, rtol=1e-5, atol=1e-5)


def test_lowering_is_cached_and_valid():
    low1 = model.lowered("agg_sum_f32")
    low2 = model.lowered("agg_sum_f32")
    assert low1 is low2
    text = low1.as_text()
    assert "func" in text  # stablehlo module


def test_canonical_shapes_divisible_by_tiles():
    from compile.kernels import aggregate as ak

    assert model.TABLE_SIZE % ak.TILE_T == 0
    assert model.BATCH_SIZE % ak.TILE_B == 0
