"""AOT pipeline tests: HLO text artifacts + manifest integrity."""

import hashlib
import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out))
    return out, manifest


def test_manifest_lists_all_entries(built):
    out, manifest = built
    assert set(manifest["entries"]) == set(model.entry_points())
    assert manifest["table_size"] == model.TABLE_SIZE
    assert manifest["batch_size"] == model.BATCH_SIZE
    assert manifest["key_words"] == model.KEY_WORDS


def test_hlo_text_is_parseable_hlo(built):
    out, manifest = built
    for name, entry in manifest["entries"].items():
        text = (out / entry["file"]).read_text()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # 64-bit-id regression guard: the text parser reassigns ids, but
        # the emitted text itself must be plain HLO, not a proto dump.
        assert "\x00" not in text, name


def test_manifest_hashes_match_files(built):
    out, manifest = built
    for name, entry in manifest["entries"].items():
        text = (out / entry["file"]).read_text()
        assert hashlib.sha256(text.encode()).hexdigest() == entry["sha256"], name
        assert len(text) == entry["bytes"], name


def test_manifest_arg_shapes(built):
    _, manifest = built
    args = manifest["entries"]["agg_sum_f32"]["args"]
    assert args[0] == {"shape": [model.TABLE_SIZE], "dtype": "float32"}
    assert args[1] == {"shape": [model.BATCH_SIZE], "dtype": "int32"}
    assert args[2] == {"shape": [model.BATCH_SIZE], "dtype": "float32"}
    hargs = manifest["entries"]["hash_fnv"]["args"]
    assert hargs[0]["shape"] == [model.BATCH_SIZE, model.KEY_WORDS]
    assert hargs[0]["dtype"] == "uint32"


def test_only_flag_builds_single_entry(tmp_path):
    manifest = aot.build(str(tmp_path), only="hash_fnv")
    assert set(manifest["entries"]) == {"hash_fnv"}
    assert os.path.exists(tmp_path / "hash_fnv.hlo.txt")


def test_manifest_json_round_trips(built):
    out, manifest = built
    on_disk = json.loads((out / "manifest.json").read_text())
    assert on_disk == manifest
