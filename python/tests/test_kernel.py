"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, ops, duplicate/padded index patterns;
this is the core correctness signal for everything the Rust runtime
executes from the AOT artifacts.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aggregate, hash_fnv, ref

SHAPES = st.sampled_from(
    [(64, 64), (2048, 256), (4096, 512), (65536, 1024), (2048, 1024), (65536, 256)]
)
OPS = st.sampled_from(["sum", "max", "min"])


def _case(table_size, batch, seed, pad_frac=0.2, dup=False):
    rng = np.random.default_rng(seed)
    if dup:
        # Force heavy duplication: draw indices from a tiny range.
        idx = rng.integers(0, max(2, table_size // 64), batch)
    else:
        idx = rng.integers(0, table_size, batch)
    pad = rng.random(batch) < pad_frac
    idx = np.where(pad, -1, idx).astype(np.int32)
    vals = rng.normal(size=batch).astype(np.float32)
    return jnp.asarray(idx), jnp.asarray(vals)


@settings(deadline=None, max_examples=12)
@given(shape=SHAPES, op=OPS, seed=st.integers(0, 2**31 - 1), dup=st.booleans())
def test_scatter_aggregate_matches_ref(shape, op, seed, dup):
    table_size, batch = shape
    idx, vals = _case(table_size, batch, seed, dup=dup)
    table = jnp.full((table_size,), aggregate.IDENTITY[op], jnp.float32)
    got = aggregate.scatter_aggregate(table, idx, vals, op=op)
    want = ref.ref_scatter_aggregate(table, idx, vals, op=op)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(deadline=None, max_examples=8)
@given(shape=SHAPES, seed=st.integers(0, 2**31 - 1))
def test_scatter_sum_i32_exact(shape, seed):
    table_size, batch = shape
    rng = np.random.default_rng(seed)
    idx = rng.integers(-1, table_size, batch).astype(np.int32)
    vals = rng.integers(-1000, 1000, batch).astype(np.int32)
    table = jnp.asarray(rng.integers(-100, 100, table_size).astype(np.int32))
    got = aggregate.scatter_aggregate(table, jnp.asarray(idx), jnp.asarray(vals), op="sum")
    want = ref.ref_scatter_aggregate(table, jnp.asarray(idx), jnp.asarray(vals), op="sum")
    np.testing.assert_array_equal(got, want)


def test_scatter_on_nonempty_table_accumulates():
    table = jnp.asarray(np.arange(64, dtype=np.float32))
    idx = jnp.asarray([0, 0, 63, -1], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 5.0, 100.0], jnp.float32)
    out = aggregate.scatter_aggregate(table, idx, vals, op="sum")
    assert out[0] == 3.0
    assert out[63] == 68.0
    assert float(jnp.sum(out)) == pytest.approx(float(jnp.sum(table)) + 8.0)


def test_all_padding_batch_is_identity():
    table = jnp.asarray(np.random.default_rng(1).normal(size=256), jnp.float32)
    idx = jnp.full((64,), -1, jnp.int32)
    vals = jnp.ones((64,), jnp.float32)
    for op in aggregate.OPS:
        out = aggregate.scatter_aggregate(table, idx, vals, op=op)
        np.testing.assert_allclose(out, table)


def test_max_min_with_duplicates():
    table = jnp.full((64,), aggregate.IDENTITY["max"], jnp.float32)
    idx = jnp.asarray([5, 5, 5, 5], jnp.int32)
    vals = jnp.asarray([1.0, 9.0, -3.0, 4.0], jnp.float32)
    out = aggregate.scatter_aggregate(table, idx, vals, op="max")
    assert out[5] == 9.0
    tmin = jnp.full((64,), aggregate.IDENTITY["min"], jnp.float32)
    out = aggregate.scatter_aggregate(tmin, idx, vals, op="min")
    assert out[5] == -3.0


def test_int_table_rejects_max():
    table = jnp.zeros((64,), jnp.int32)
    idx = jnp.zeros((64,), jnp.int32)
    with pytest.raises(ValueError):
        aggregate.scatter_aggregate(table, idx, idx, op="max")


def test_unknown_op_rejected():
    table = jnp.zeros((64,), jnp.float32)
    idx = jnp.zeros((64,), jnp.int32)
    with pytest.raises(ValueError):
        aggregate.scatter_aggregate(table, idx, table[:64], op="topk")


@settings(deadline=None, max_examples=12)
@given(
    batch=st.sampled_from([256, 512, 1024]),
    n_words=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_fnv_hash_matches_ref(batch, n_words, seed):
    rng = np.random.default_rng(seed)
    words = jnp.asarray(
        rng.integers(0, 2**32, (batch, n_words), dtype=np.uint64).astype(np.uint32)
    )
    got = hash_fnv.fnv1a_hash(words)
    want = ref.ref_fnv1a_hash(words)
    np.testing.assert_array_equal(got, want)


def test_fnv_known_vector():
    # h(0) = (2166136261 ^ 0) * 16777619 mod 2^32 — hand-checkable chain;
    # also pinned in rust/src/switch/hash.rs::tests so both languages
    # agree on the constant.
    words = jnp.zeros((256, 1), jnp.uint32)
    h = int(hash_fnv.fnv1a_hash(words)[0])
    assert h == (2166136261 * 16777619) % (1 << 32) == 84696351

    words2 = jnp.tile(jnp.asarray([[0xDEADBEEF, 0x12345678]], jnp.uint32), (256, 1))
    assert int(hash_fnv.fnv1a_hash(words2)[0]) == ref.fnv1a_hash_py(
        [0xDEADBEEF, 0x12345678]
    )


def test_fnv_zero_padding_changes_hash():
    # Word-level hashing means trailing zero words are significant —
    # the Rust side must always pack to the group's full width.
    w1 = jnp.zeros((256, 2), jnp.uint32).at[:, 0].set(7)
    w2 = jnp.zeros((256, 4), jnp.uint32).at[:, 0].set(7)
    assert int(hash_fnv.fnv1a_hash(w1)[0]) != int(hash_fnv.fnv1a_hash(w2)[0])
